"""Hypothesis property tests on the system's invariants."""

import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.estimator import AdaptiveTokenEstimator, BiasStore, DriftConfig
from repro.core.policies import make_policy
from repro.core.queues import TenantQueueManager
from repro.core.request import Category, JobClass, Request, TenantTier
from repro.core.admission import AdmissionController
from repro.distributed.fault_tolerance import elastic_plan
from repro.serving.kv_cache import PagedAllocator
from repro.serving.metrics import percentile

CATS = list(Category)
TIERS = list(TenantTier)


@given(st.lists(st.floats(min_value=1.0, max_value=2000.0),
                min_size=1, max_size=200),
       st.floats(min_value=0.01, max_value=1.0))
def test_ema_bias_stays_in_observed_hull(observations, alpha):
    """EMA bias never escapes [min, max] of (clipped) observed ratios
    union the initial value — no runaway."""
    cfg = DriftConfig(ema_alpha=alpha)
    store = BiasStore(cfg)
    t_base = cfg.base_estimates[Category.SUMMARY]
    lo, hi = cfg.bias_clip
    ratios = [min(max(o / t_base, lo), hi) for o in observations]
    for o in observations:
        store.update(Category.SUMMARY, o)
    b = store.get(Category.SUMMARY)
    assert min(ratios + [1.0]) - 1e-9 <= b <= max(ratios + [1.0]) + 1e-9


@given(st.floats(min_value=1.0, max_value=1e6))
def test_classification_total_and_ordered(budget):
    est = AdaptiveTokenEstimator(DriftConfig())
    jc = est.classify_budget(budget)
    assert jc in (JobClass.SHORT, JobClass.MEDIUM, JobClass.LONG)


@given(st.integers(min_value=0, max_value=5000),
       st.integers(min_value=0, max_value=5000))
def test_estimate_monotone_in_prompt_tokens(a, b):
    """Longer prompts never get smaller budgets (F_input monotone +
    additive T_input)."""
    est = AdaptiveTokenEstimator(DriftConfig())
    ea = est.estimate(Category.TECHNICAL, TenantTier.STANDARD, a)
    eb = est.estimate(Category.TECHNICAL, TenantTier.STANDARD, b)
    if a <= b:
        assert ea.t_budget <= eb.t_budget
    else:
        assert eb.t_budget <= ea.t_budget


@settings(max_examples=50)
@given(st.lists(st.tuples(st.sampled_from(CATS), st.sampled_from(TIERS)),
                min_size=1, max_size=60),
       st.sampled_from(["fifo", "priority", "sjf", "weighted", "aging"]))
def test_policies_conserve_requests(entries, policy_name):
    """Every admitted request is dispatched exactly once, none invented."""
    mgr = TenantQueueManager()
    adm = AdmissionController(AdaptiveTokenEstimator(DriftConfig()), mgr)
    ids = set()
    for i, (cat, tier) in enumerate(entries):
        r = Request(tenant=tier, category=cat, prompt="p q r")
        adm.admit(r, now=float(i))
        ids.add(r.req_id)
    pol = make_policy(policy_name)
    seen = set()
    for _ in range(len(entries)):
        r = pol.select(mgr, now=1e6)
        assert r is not None
        assert r.req_id not in seen
        seen.add(r.req_id)
    assert seen == ids
    assert pol.select(mgr, now=1e6) is None


@settings(max_examples=50)
@given(st.lists(st.tuples(st.integers(1, 300), st.integers(1, 300)),
                min_size=1, max_size=40))
def test_paged_allocator_conservation(seqs):
    """Pages are never double-allocated; free+used == total always."""
    alloc = PagedAllocator(n_pages=4096, page_size=16, pages_per_seq=64)
    live = {}
    for sid, (prompt, gen) in enumerate(seqs):
        pages = alloc.alloc(sid, prompt)
        assert len(set(pages)) == len(pages)
        live[sid] = list(pages)
        for _ in range(gen):
            fresh = alloc.extend(sid, 1)
            live[sid].extend(fresh)
    all_pages = [p for ps in live.values() for p in ps]
    assert len(set(all_pages)) == len(all_pages)          # no aliasing
    assert alloc.free_pages + len(all_pages) == 4096
    for sid in list(live):
        alloc.free(sid)
    assert alloc.free_pages == 4096


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3),      # op: insert/acquire/release/evict
                          st.integers(0, 5),      # prefix group
                          st.integers(1, 16),     # key length (pages)
                          st.integers(1, 20)),    # evict amount / pick
                min_size=1, max_size=80))
def test_prefix_tree_refcount_page_conservation(ops):
    """Radix prefix cache under random insert/acquire/release/evict
    sequences: pages are conserved against the allocator (free + tree ==
    total; the tree holds no seq tables), locked paths never lose
    resident pages, refcounts never underflow, and a fully-released
    tree drains to empty under eviction. The pool (32 pages) is far
    smaller than the worst-case population (6 groups x 16 pages), so
    insert-under-pressure eviction/truncation is exercised, not just
    explicit evict calls."""
    from repro.serving.kv_cache import PagedAllocator, PrefixTree

    N_PAGES = 32
    alloc = PagedAllocator(n_pages=N_PAGES, page_size=8, pages_per_seq=8)
    tree = PrefixTree(alloc)
    held = []           # (locked node, key, pages matched at lock time)
    key = lambda g, k: tuple((g, i) for i in range(k))
    for t, (op, g, k, n) in enumerate(ops):
        if op == 0:
            tree.insert(key(g, k), float(t))
        elif op == 1:
            node, matched = tree.match(key(g, k), float(t))
            if matched:
                tree.lock(node)
                held.append((node, key(g, k), matched))
        elif op == 2 and held:
            node, _, _ = held.pop(n % len(held))
            tree.release(node)
        elif op == 3:
            tree.evict(n)
        # conservation: every page is either free or owned by the tree
        assert alloc.free_pages + tree.total_pages() == N_PAGES
        # a locked path keeps its resident pages pinned
        for _, hkey, matched in held:
            assert tree.cached_tokens(hkey) // tree.page_size >= matched
        for node in tree._nodes():
            assert node.refcount >= 0
    for node, _, _ in held:
        tree.release(node)
    tree.evict(N_PAGES)
    assert tree.total_pages() == 0
    assert alloc.free_pages == N_PAGES


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4),      # op: admit/chunk/extend/donate/retire
                          st.integers(0, 4),      # prefix group
                          st.integers(1, 12),     # prompt tokens / pick
                          st.integers(0, 10)),    # decode tokens / extend amount
                min_size=1, max_size=80))
def test_engine_page_ledger_conservation(ops):
    """The engine's paged bookkeeping (PagedSeqLedger over one
    allocator + PrefixTree) under random admit / chunk-consume /
    decode-extend / donate / retire sequences, mirroring the PR-4
    simulator-side property: every page is free, privately owned by a
    live sequence, or resident in the tree (free + owned + cached ==
    pool at every point); donation never double-owns a page; and after
    retiring everything and a failure wipe (``clear``) no refcount
    strands a page — the pool drains to fully free.

    The pool (24 pages) sits far below the worst-case population
    (5 groups x 3-page keys + per-seq privates), so insert-under-
    pressure eviction and the OutOfPages admission path are exercised,
    not just the happy path."""
    from repro.serving.kv_cache import (OutOfPagesError, PagedAllocator,
                                        PagedSeqLedger, PrefixTree)

    N_PAGES = 24
    P = 4
    alloc = PagedAllocator(n_pages=N_PAGES, page_size=P, pages_per_seq=8)
    tree = PrefixTree(alloc)
    ledger = PagedSeqLedger(alloc, tree, cache_pages_budget=10)
    key = lambda g, pages: tuple((g, i) for i in range(pages))
    live = {}            # seq_id -> remaining chunk tokens (scheduling toy)
    next_seq = 0
    for t, (op, g, k, n) in enumerate(ops):
        if op == 0:       # admit: prompt of k*P tokens, key up to 3 pages
            try:
                cached = ledger.admit(next_seq, k * P,
                                      key(g, min(k, 3)), float(t))
            except OutOfPagesError:
                pass      # pool genuinely full of pinned pages: refused
            else:
                assert cached % P == 0
                assert cached <= k * P
                live[next_seq] = k * P - cached
                next_seq += 1
        elif op == 1 and live:        # consume a prefill chunk
            sid = sorted(live)[n % len(live)]
            live[sid] = max(live[sid] - k, 0)
        elif op == 2 and live:        # decode growth
            sid = sorted(live)[k % len(live)]
            try:
                fresh, cows = ledger.extend(sid, n)
            except OutOfPagesError:
                pass
            else:
                assert not cows       # full-page keys: suffix is private
        elif op == 3 and live:        # prefill completion -> donation
            sid = sorted(live)[k % len(live)]
            if live[sid] == 0:
                ledger.donate(sid, float(t))
        elif op == 4 and live:        # retirement
            sid = sorted(live)[k % len(live)]
            ledger.free(sid)
            del live[sid]
        # conservation: every page accounted exactly once
        assert alloc.free_pages + ledger.owned_pages() \
            + tree.total_pages() == N_PAGES
        for node in tree._nodes():
            assert node.refcount >= 0
    for sid in list(live):
        ledger.free(sid)
    assert ledger.owned_pages() == 0
    # live pins are gone: the wipe must strand nothing
    assert all(nd.refcount == 0 for nd in tree._nodes())
    tree.clear()
    assert alloc.free_pages == N_PAGES


@given(st.lists(st.floats(min_value=-1e6, max_value=1e6,
                          allow_nan=False), min_size=1, max_size=300),
       st.floats(min_value=0, max_value=100))
def test_percentile_matches_numpy(values, p):
    import numpy as np
    ours = percentile(values, p)
    theirs = float(np.percentile(np.array(values), p))
    assert math.isclose(ours, theirs, rel_tol=1e-9, abs_tol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=1, max_value=900),
                          st.integers(min_value=1, max_value=200)),
                min_size=1, max_size=40),
       st.one_of(st.none(), st.integers(min_value=1, max_value=512)),
       st.booleans())
def test_step_engine_token_accounting_conserves(shapes, chunk, joins):
    """Iteration-level execution conserves tokens: per-step prefill plus
    decode emissions sum to exactly prompt + observed output for every
    request, for any chunk budget, with joins on or off."""
    from dataclasses import replace as _replace

    from repro.core.scheduler import DriftScheduler
    from repro.serving.cost_model import L4_QWEN_1_8B
    from repro.serving.simulator import SimConfig, WorkerSimulator
    from repro.workload.generator import ArrivalPlan, GeneratorConfig

    reqs = [Request(tenant=TIERS[i % len(TIERS)],
                    category=CATS[i % len(CATS)],
                    prompt="p", prompt_tokens=prompt,
                    true_output_tokens=out)
            for i, (prompt, out) in enumerate(shapes)]
    plan = ArrivalPlan(
        calibration=[(0.01 * i, r) for i, r in enumerate(reqs)],
        stress=[],
        config=GeneratorConfig(total_requests=len(reqs),
                               calibration_requests=len(reqs)))
    sched = DriftScheduler(policy="fifo", config=DriftConfig())
    sim = WorkerSimulator(
        sched, plan,
        SimConfig(seed=0, step_engine=True, continuous_joins=joins,
                  chunk_prefill_tokens=chunk, batch_capacity=8),
        cost_model=_replace(L4_QWEN_1_8B, jitter_sigma=0.0))
    m = sim.run()
    assert m.n_completed == len(reqs)
    for r in sched.completed:
        assert sim.token_ledger[r.req_id] == \
            [r.prompt_tokens, r.observed_output_tokens]
        assert r.observed_output_tokens == min(r.true_output_tokens,
                                               r.max_tokens)


@given(st.lists(st.floats(min_value=-1e6, max_value=1e6,
                          allow_nan=False), min_size=1, max_size=400),
       st.sampled_from([0.5, 0.9, 0.95, 0.99]))
def test_p2_quantile_stays_in_observed_hull(values, p):
    """The streaming P² estimate never escapes [min, max] of the
    observed samples (marker heights are convex combinations of
    observations), and is exact while n <= 5."""
    from repro.obs.series import P2Quantile

    q = P2Quantile(p)
    for x in values:
        q.add(x)
    est = q.value()
    assert min(values) - 1e-9 <= est <= max(values) + 1e-9
    if len(values) <= 5:
        assert math.isclose(est, percentile(values, p * 100.0),
                            rel_tol=1e-9, abs_tol=1e-9)


@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=0, max_value=500))
def test_recorder_stride_sampling_exact_count(stride, emissions):
    """Counter-strided sampling records exactly ceil(m / stride) of m
    emissions — deterministic, first emission always recorded."""
    from repro.obs.events import DECODE_STEP, TraceRecorder

    rec = TraceRecorder(sample_every={DECODE_STEP: stride})
    for i in range(emissions):
        rec.emit(float(i), DECODE_STEP, req_id=1)
    assert len(rec.events()) == -(-emissions // stride)
    assert rec.stats()["by_kind"].get(DECODE_STEP, 0) == emissions


@settings(max_examples=60)
@given(st.lists(st.tuples(st.integers(0, 6),      # prefill chunks
                          st.integers(0, 40),     # decode steps
                          st.booleans(),          # routed?
                          st.booleans()),         # shed at the door?
                min_size=1, max_size=20))
def test_generated_lifecycles_always_validate(chains):
    """Any chain built from the legal grammar (arrive -> admit ->
    [route] -> prefill* -> first_token -> decode* -> complete, or an
    immediate shed) passes validate_lifecycles; truncating its terminal
    is flagged iff terminals are required."""
    from repro.obs import events as tr

    evs, seq = [], 0

    def emit(ts, kind, req_id, **data):
        nonlocal seq
        evs.append(tr.TraceEvent(seq=seq, ts=ts, kind=kind,
                                 req_id=req_id, data=data))
        seq += 1

    any_route = any(routed and not shed
                    for _, _, routed, shed in chains)
    for rid, (chunks, decodes, routed, shed) in enumerate(chains):
        t = float(rid)
        emit(t, tr.ARRIVE, rid)
        if shed:
            emit(t, tr.SHED, rid, reason="overload")
            continue
        emit(t, tr.ADMIT, rid)
        if any_route:       # route-ful streams require routes pre-exec
            emit(t, tr.ROUTE, rid, stage="admit")
        for c in range(chunks):
            t += 0.1
            emit(t, tr.PREFILL_CHUNK, rid, tokens=16)
        t += 0.1
        emit(t, tr.FIRST_TOKEN, rid, ttft=t - rid)
        for d in range(decodes):
            t += 0.05
            emit(t, tr.DECODE_STEP, rid)
        t += 0.05
        emit(t, tr.COMPLETE, rid, e2e=t - rid, ttft=0.1)
    assert tr.validate_lifecycles(evs) == []
    truncated = evs[:-1]
    if evs[-1].kind == tr.COMPLETE:
        assert tr.validate_lifecycles(truncated)
        assert tr.validate_lifecycles(truncated,
                                      require_terminal=False) == []


@given(st.integers(min_value=1, max_value=4096))
def test_elastic_plan_always_uses_most_chips(n):
    plan = elastic_plan(n, model_parallel=16)
    dp, tp = plan.mesh_shape
    assert dp * tp <= n
    assert dp * tp + plan.dropped_chips == n
    # never wastes a full TP group
    assert n - dp * tp < tp


# ---------------------------------------------------------------------
# vectorized simulator core (repro.serving.vector_sim)
# ---------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),   # workload seed
       st.sampled_from(["fifo", "priority", "sjf", "weighted"]),
       st.integers(min_value=1, max_value=3),        # workers
       st.integers(min_value=2, max_value=8),        # batch capacity
       st.booleans(),                                # chunked prefill
       st.booleans(),                                # continuous joins
       st.booleans(),                                # prefix cache
       st.booleans())                                # preemption
def test_vector_core_conservation(seed, policy, n_workers, cap,
                                  chunked, joins, prefix, preempt):
    """Conservation laws of the flat-array simulator core under
    randomized drivers, checked at every step boundary: prefix-pool
    pages are partitioned between the free list and the radix tree
    (free + resident == pool), and every arrived request sits in
    exactly one lifecycle bucket (queued + running + done == arrived).
    ``tests/test_vector_parity.py`` carries the fixed-seed fallback of
    this property — hypothesis is a CI-only dependency."""
    from repro.serving.cost_model import L4_QWEN_1_8B
    from repro.serving.simulator import SimConfig
    from repro.serving.vector_sim import (S_COMPLETED, S_CREATED,
                                          S_FAILED,
                                          VectorWorkerSimulator)
    from repro.workload.generator import (GeneratorConfig, VectorPlan,
                                          WorkloadGenerator)

    gen = WorkloadGenerator(GeneratorConfig(
        total_requests=40, calibration_requests=6,
        shared_prefix_tokens=96 if prefix else 0,
        prefix_groups_per_tenant=2, seed=seed))
    vplan = VectorPlan.from_plan(gen.plan())
    cfg = SimConfig(
        step_engine=True, n_workers=n_workers, batch_capacity=cap,
        chunk_prefill_tokens=48 if chunked else None,
        continuous_joins=joins, prefix_cache=prefix,
        fail_times=(4.0,) if preempt else (), repair_time=2.0,
        seed=seed)
    vec = VectorWorkerSimulator(vplan, cfg, L4_QWEN_1_8B, policy=policy)

    checks = {"n": 0}
    inner = vec._finish_step

    def checked(wid, gen_, now):
        done = inner(wid, gen_, now)
        st = vec.state
        if vec.prefix_tree is not None:
            alloc = vec.prefix_tree.allocator
            assert (alloc.free_pages + vec.prefix_tree.total_pages()
                    == alloc.n_pages)
        n = len(st.req_id)
        arrived = n - int((st.state[:n] == S_CREATED).sum())
        in_buckets = int((st.state[:n] > S_CREATED).sum()
                         - (st.state[:n] == S_FAILED).sum())
        assert in_buckets == arrived
        checks["n"] += 1
        return done

    vec._finish_step = checked
    vec.run()
    assert checks["n"] > 0
    assert int((vec.state.state == S_COMPLETED).sum()) == len(vplan)
