"""Unit tests for the paper's core equations (Eq. 1-6)."""

import math

import pytest

from repro.core.estimator import AdaptiveTokenEstimator, BiasStore, DriftConfig
from repro.core.request import Category, JobClass, TenantTier


def test_eq2_factorization():
    """T_estimated_output = T_base * B * S * F exactly (Eq. 2)."""
    est = AdaptiveTokenEstimator(DriftConfig())
    e = est.estimate(Category.SUMMARY, TenantTier.PREMIUM, prompt_tokens=12)
    assert e.est_output_tokens == pytest.approx(
        e.t_base * e.bias * e.safety * e.f_input)


def test_eq1_budget_includes_input():
    est = AdaptiveTokenEstimator(DriftConfig())
    e = est.estimate(Category.SHORT_QA, TenantTier.BATCH, prompt_tokens=40)
    assert e.t_budget == pytest.approx(40 + e.est_output_tokens)


def test_eq3_classification_thresholds():
    """short <= 128 < medium <= 512 < long (Eq. 3-4)."""
    est = AdaptiveTokenEstimator(DriftConfig())
    assert est.classify_budget(128.0) is JobClass.SHORT
    assert est.classify_budget(128.0001) is JobClass.MEDIUM
    assert est.classify_budget(512.0) is JobClass.MEDIUM
    assert est.classify_budget(512.0001) is JobClass.LONG


def test_eq5_ema_update():
    """B_new = (1-a) B_old + a * (T_actual / T_base) (Eq. 5-6)."""
    cfg = DriftConfig(ema_alpha=0.25)
    store = BiasStore(cfg)
    t_base = cfg.base_estimates[Category.REPORT]
    b1 = store.update(Category.REPORT, t_actual=0.5 * t_base)
    assert b1 == pytest.approx(0.75 * 1.0 + 0.25 * 0.5)
    b2 = store.update(Category.REPORT, t_actual=0.5 * t_base)
    assert b2 == pytest.approx(0.75 * b1 + 0.25 * 0.5)


def test_bias_off_freezes_estimates():
    cfg = DriftConfig(bias_enabled=False)
    est = AdaptiveTokenEstimator(cfg)
    before = est.estimate(Category.SUMMARY, TenantTier.STANDARD, 10)
    for _ in range(50):
        est.feedback(Category.SUMMARY, 10.0)
    after = est.estimate(Category.SUMMARY, TenantTier.STANDARD, 10)
    assert before.est_output_tokens == after.est_output_tokens
    assert after.bias == cfg.bias_init


def test_bias_updates_are_per_category():
    est = AdaptiveTokenEstimator(DriftConfig())
    est.feedback(Category.REPORT, 10.0)
    assert est.bias_store.get(Category.REPORT) < 1.0
    assert est.bias_store.get(Category.SHORT_QA) == 1.0


def test_bias_measured_clip():
    cfg = DriftConfig(ema_alpha=1.0, bias_clip=(0.1, 4.0))
    store = BiasStore(cfg)
    t_base = cfg.base_estimates[Category.SHORT_QA]
    assert store.update(Category.SHORT_QA, 1e9) == pytest.approx(4.0)
    assert store.update(Category.SHORT_QA, 0.0) == pytest.approx(0.1)


def test_f_input_monotone_and_clipped():
    cfg = DriftConfig()
    est = AdaptiveTokenEstimator(cfg)
    vals = [est.f_input(n) for n in (1, 4, 16, 64, 256, 100_000)]
    assert all(a <= b for a, b in zip(vals, vals[1:]))
    lo, hi = cfg.f_input_clip
    assert all(lo <= v <= hi for v in vals)


def test_tenant_safety_ordering():
    """Premium over-provisions more than Standard more than Batch."""
    est = AdaptiveTokenEstimator(DriftConfig())
    outs = [est.estimate(Category.TECHNICAL, t, 20).est_output_tokens
            for t in (TenantTier.PREMIUM, TenantTier.STANDARD,
                      TenantTier.BATCH)]
    assert outs[0] > outs[1] > outs[2]


def test_bias_store_checkpoint_roundtrip():
    cfg = DriftConfig()
    store = BiasStore(cfg)
    for i in range(5):
        store.update(Category.SUMMARY, 100.0 + i)
    state = store.state_dict()
    fresh = BiasStore(cfg)
    fresh.load_state_dict(state)
    assert fresh.snapshot() == store.snapshot()
    assert fresh.update_counts() == store.update_counts()
