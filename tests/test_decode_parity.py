"""Cache-path correctness: for every family, teacher-forced full
``forward`` logits at position t must match ``prefill`` (up to t) +
``decode_step`` continuation. This validates the KV ring caches, SSM
states, conv rings, cross-KV reuse, and per-slot position handling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models.registry import get_api

ATOL = 6e-2   # bf16 params; logits compared in f32


def _inputs(cfg, key, B, L):
    tok = jax.random.randint(key, (B, L), 0, cfg.vocab)
    batch = {"tokens": tok}
    if cfg.family == "vlm":
        batch["patches"] = 0.02 * jax.random.normal(
            key, (B, cfg.prefix_len, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_logits_match_forward(arch):
    """prefill(tokens) last-position logits == forward(tokens)[:, -1]."""
    cfg = smoke_config(arch)
    api = get_api(cfg)
    key = jax.random.PRNGKey(3)
    params = api.init(cfg, key)
    B, L = 2, 16
    batch = _inputs(cfg, key, B, L)
    full, _ = api.forward(cfg, params, batch)
    pre, _cache = api.prefill(cfg, params, batch, max_len=32)
    np.testing.assert_allclose(
        np.asarray(pre, np.float32),
        np.asarray(full[:, -1], np.float32), atol=ATOL, rtol=ATOL)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_continuation_matches_forward(arch):
    """prefill(t[:k]) then decode t[k], t[k+1] reproduces forward logits."""
    cfg = smoke_config(arch)
    api = get_api(cfg)
    key = jax.random.PRNGKey(4)
    params = api.init(cfg, key)
    B, L, k = 2, 12, 9
    batch = _inputs(cfg, key, B, L)
    tokens = batch["tokens"]

    full, _ = api.forward(cfg, params, batch)
    if cfg.family == "vlm":
        full = full[:, cfg.prefix_len:]

    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, :k]
    logits, cache = api.prefill(cfg, params, pre_batch, max_len=32)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(full[:, k - 1], np.float32),
                               atol=ATOL, rtol=ATOL)
    pos_base = k + (cfg.prefix_len if cfg.family == "vlm" else 0)
    for i in range(L - k):
        logits, cache = api.decode_step(cfg, params, cache,
                                        tokens[:, k + i],
                                        jnp.asarray(pos_base + i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full[:, k + i], np.float32),
            atol=ATOL, rtol=ATOL,
            err_msg=f"{arch}: decode step {i} diverged")


def test_sliding_window_ring_cache_parity():
    """Windowed arch decoding past the window: ring cache == full mask."""
    cfg = smoke_config("h2o-danube-1.8b")      # window 16
    api = get_api(cfg)
    key = jax.random.PRNGKey(5)
    params = api.init(cfg, key)
    B, L = 1, 24                                # prefill shorter than window
    tokens = jax.random.randint(key, (B, L + 8), 0, cfg.vocab)

    full, _ = api.forward(cfg, params, {"tokens": tokens})
    logits, cache = api.prefill(cfg, params, {"tokens": tokens[:, :L]},
                                max_len=cfg.sliding_window)
    for i in range(8):                          # decode crosses the window
        logits, cache = api.decode_step(cfg, params, cache,
                                        tokens[:, L + i],
                                        jnp.asarray(L + i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full[:, L + i], np.float32),
            atol=ATOL, rtol=ATOL, err_msg=f"window step {i}")


def test_per_slot_positions_match_lockstep():
    """Vector-pos decode (continuous batching) == scalar-pos decode when
    the depths coincide."""
    cfg = smoke_config("smollm-135m")
    api = get_api(cfg)
    key = jax.random.PRNGKey(6)
    params = api.init(cfg, key)
    B, L = 2, 10
    tokens = jax.random.randint(key, (B, L + 1), 0, cfg.vocab)
    _, cache_a = api.prefill(cfg, params, {"tokens": tokens[:, :L]},
                             max_len=32)
    _, cache_b = api.prefill(cfg, params, {"tokens": tokens[:, :L]},
                             max_len=32)
    la, _ = api.decode_step(cfg, params, cache_a, tokens[:, L],
                            jnp.asarray(L, jnp.int32))
    lb, _ = api.decode_step(cfg, params, cache_b, tokens[:, L],
                            jnp.full((B,), L, jnp.int32))
    np.testing.assert_allclose(np.asarray(la, np.float32),
                               np.asarray(lb, np.float32),
                               atol=1e-5, rtol=1e-5)
