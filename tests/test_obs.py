"""Observability layer: trace recorder semantics, streaming P²/window
aggregates, SLO burn-rate monitors, lifecycle validation, Chrome-trace
export, and — the load-bearing guarantee — traced runs bit-identical
to untraced runs on every execution surface (worker simulator, cluster
simulator, and the live JAX engine)."""

import json
import math
import random
from dataclasses import dataclass

import pytest

from repro.cluster import (ClusterConfig, ClusterSimulator, GlobalAdmission,
                           RoleAutoscaler, RoleAutoscalerConfig)
from repro.core.estimator import DriftConfig
from repro.core.scheduler import DriftScheduler
from repro.obs import (DEFAULT_SAMPLE_EVERY, NULL_RECORDER, P2Quantile,
                       SeriesBank, SlidingWindow, SloMonitor, SloTarget,
                       StreamSummary, TraceEvent, TraceRecorder,
                       get_recorder, percentile, resolve_recorder,
                       set_recorder, to_chrome_trace, validate_chrome_trace,
                       validate_lifecycles, write_chrome_trace)
from repro.obs import events as tr
from repro.serving.cost_model import L4_MAX_DRIVEN
from repro.serving.simulator import SimConfig, WorkerSimulator
from repro.workload.generator import (GeneratorConfig, WorkloadGenerator,
                                      cluster_stress_config)

# full fidelity: every decode step and gauge lands in the ring, so
# lifecycle chains are complete and validatable
FULL = {"decode_step": 1, "gauge": 1}


# --- recorder ----------------------------------------------------------

def test_emit_records_and_counts():
    rec = TraceRecorder()
    rec.emit(1.0, tr.ARRIVE, req_id=7, tenant="premium")
    rec.emit(2.0, tr.COMPLETE, req_id=7, tenant="premium", e2e=1.0)
    evs = rec.events()
    assert [e.kind for e in evs] == ["arrive", "complete"]
    assert evs[0].seq == 0 and evs[1].seq == 1
    assert evs[1].data == {"e2e": 1.0}
    s = rec.stats()
    assert s["emitted"] == 2 and s["recorded"] == 2
    assert s["by_kind"] == {"arrive": 1, "complete": 1}
    assert rec.last_ts == 2.0


def test_unknown_kind_rejected():
    rec = TraceRecorder()
    with pytest.raises(ValueError, match="unknown event kind"):
        rec.emit(0.0, "no_such_kind")
    with pytest.raises(ValueError, match="unknown event kind"):
        TraceRecorder(sample_every={"no_such_kind": 2})
    with pytest.raises(ValueError):
        TraceRecorder(sample_every={tr.DECODE_STEP: 0})
    with pytest.raises(ValueError):
        TraceRecorder(capacity=0)


def test_stride_sampling_is_counter_based():
    rec = TraceRecorder(sample_every={tr.DECODE_STEP: 4})
    for i in range(10):
        rec.emit(float(i), tr.DECODE_STEP, req_id=1)
    # emissions 0, 4, 8 recorded (first always lands)
    assert [e.ts for e in rec.events()] == [0.0, 4.0, 8.0]
    s = rec.stats()
    assert s["by_kind"]["decode_step"] == 10       # emitted, pre-sampling
    assert s["recorded"] == 3
    # unlisted kinds record 1:1 regardless of the default strides
    assert rec.sample_every[tr.GAUGE] == DEFAULT_SAMPLE_EVERY[tr.GAUGE]


def test_ring_overflow_drops_oldest():
    rec = TraceRecorder(capacity=10)
    for i in range(25):
        rec.emit(float(i), tr.ARRIVE, req_id=i)
    evs = rec.events()
    assert len(evs) == 10
    assert [e.req_id for e in evs] == list(range(15, 25))
    assert rec.stats()["dropped_overflow"] == 15


def test_observers_see_every_emission_pre_sampling():
    seen = []

    class Spy:
        def on_event(self, e):
            seen.append(e.kind)

    rec = TraceRecorder(sample_every={tr.DECODE_STEP: 100}, observers=(Spy(),))
    for i in range(10):
        rec.emit(float(i), tr.DECODE_STEP, req_id=1)
    assert len(seen) == 10                 # observer: all emissions
    assert len(rec.events()) == 1          # ring: strided


def test_null_recorder_is_inert():
    assert NULL_RECORDER.enabled is False
    NULL_RECORDER.emit(0.0, "anything_goes", bogus=1)   # never raises
    assert NULL_RECORDER.events() == []
    assert NULL_RECORDER.stats()["emitted"] == 0
    assert NULL_RECORDER.begin_segment("x") == 0


def test_global_recorder_plumbing():
    assert get_recorder() is NULL_RECORDER
    rec = TraceRecorder()
    try:
        assert set_recorder(rec) is rec
        assert get_recorder() is rec
        # resolve: explicit wins, None falls back to the global
        other = TraceRecorder()
        assert resolve_recorder(other) is other
        assert resolve_recorder(None) is rec
        # components resolve at construction time
        sim = WorkerSimulator(DriftScheduler(), config=SimConfig())
        assert sim.trace is rec
    finally:
        set_recorder(None)
    assert get_recorder() is NULL_RECORDER
    sim = WorkerSimulator(DriftScheduler(), config=SimConfig())
    assert sim.trace is NULL_RECORDER


def test_begin_segment_stamps_events():
    rec = TraceRecorder()
    rec.emit(0.0, tr.ARRIVE, req_id=1)
    rec.begin_segment("arm_a")
    rec.emit(1.0, tr.ARRIVE, req_id=2)
    rec.begin_segment("arm_b")
    rec.emit(2.0, tr.ARRIVE, req_id=3)
    assert [e.seg for e in rec.events()] == [0, 1, 2]
    assert rec.stats()["segments"] == ["arm_a", "arm_b"]


# --- P² quantiles & windows --------------------------------------------

def test_p2_exact_for_small_n():
    q = P2Quantile(0.5)
    assert math.isnan(q.value())
    for x in (5.0, 1.0, 3.0):
        q.add(x)
    assert q.value() == pytest.approx(percentile([5.0, 1.0, 3.0], 50))


def test_p2_tracks_exact_within_sample_range_bound():
    """The accuracy contract docs/observability.md documents: on the
    unimodal latency-like distributions used here, P² estimates stay
    within 5% of the sample range of the exact percentile."""
    rng = random.Random(0)
    for p in (0.50, 0.95, 0.99):
        for dist in ("lognormal", "uniform", "exponential"):
            xs = []
            q = P2Quantile(p)
            for _ in range(5000):
                if dist == "lognormal":
                    x = rng.lognormvariate(0.0, 0.7)
                elif dist == "uniform":
                    x = rng.uniform(0.0, 10.0)
                else:
                    x = rng.expovariate(0.5)
                xs.append(x)
                q.add(x)
            exact = percentile(xs, p * 100.0)
            bound = 0.05 * (max(xs) - min(xs))
            assert abs(q.value() - exact) <= bound, \
                f"P²({p}) on {dist}: {q.value():.4f} vs exact " \
                f"{exact:.4f} (bound {bound:.4f})"


def test_p2_rejects_bad_quantile():
    with pytest.raises(ValueError):
        P2Quantile(0.0)
    with pytest.raises(ValueError):
        P2Quantile(1.0)


def test_stream_summary_mirrors_latency_stats_keys():
    s = StreamSummary()
    empty = s.as_dict()
    assert empty["n"] == 0 and math.isnan(empty["mean"])
    for x in range(1, 101):
        s.add(float(x))
    d = s.as_dict()
    assert d["n"] == 100
    assert d["mean"] == pytest.approx(50.5)
    assert d["min"] == 1.0 and d["max"] == 100.0
    assert d["p50"] == pytest.approx(percentile(
        [float(x) for x in range(1, 101)], 50), rel=0.05)
    assert set(d) >= {"n", "mean", "p50", "p95", "p99"}


def test_sliding_window_trims_and_rates():
    w = SlidingWindow(10.0)
    for t in range(20):
        w.add(float(t))
    assert w.count(19.0) == 11            # ts in [9, 19] survive the cutoff
    assert w.rate(19.0) == pytest.approx(1.1)
    assert w.mean(19.0) == pytest.approx(1.0)
    assert w.count(100.0) == 0
    assert math.isnan(w.mean(100.0))
    with pytest.raises(ValueError):
        SlidingWindow(0.0)


def test_series_bank_aggregates_from_events():
    bank = SeriesBank(window=60.0)
    rec = TraceRecorder(observers=(bank,))
    for i in range(10):
        t = float(i)
        rec.emit(t, tr.ARRIVE, req_id=i, tenant="standard")
        rec.emit(t + 0.1, tr.PREFIX_HIT if i % 2 else tr.PREFIX_MISS,
                 req_id=i)
        rec.emit(t + 0.2, tr.DRIFT, req_id=i, abs_error=2.0)
        rec.emit(t + 0.5, tr.COMPLETE, req_id=i, tenant="standard",
                 e2e=0.5, ttft=0.2, inter_token=0.01)
    rec.emit(9.9, tr.GAUGE, name="queue_depth", value=3)
    snap = bank.snapshot()
    assert snap["e2e"]["n"] == 10
    assert snap["ttft"]["mean"] == pytest.approx(0.2)
    assert snap["windowed"]["drift_mae"] == pytest.approx(2.0)
    assert bank.prefix_hit_rate() == pytest.approx(0.5)
    assert snap["gauges"]["queue_depth"]["value"] == 3
    assert snap["windowed"]["arrival_rate"] == pytest.approx(10 / 60.0)


# --- SLO monitors ------------------------------------------------------

def _mon(**kw):
    return SloMonitor(targets={"premium": SloTarget(ttft=1.0, e2e=10.0,
                                                    attainment=0.90)},
                      windows=(60.0, 600.0), **kw)


def test_slo_ok_warn_page_transitions():
    # budget = 0.10: warn needs >=10% violating, page needs >=60%
    mon = _mon()
    assert mon.status(0.0)["premium"]["state"] == "ok"   # no data = ok
    for i in range(100):
        mon.observe("premium", float(i) * 0.1, e2e=5.0)  # all meeting
    assert mon.status()["premium"]["state"] == "ok"
    mon2 = _mon()
    for i in range(100):                     # 20% violating -> warn
        mon2.observe("premium", float(i) * 0.1,
                     e2e=20.0 if i % 5 == 0 else 5.0)
    st = mon2.status()["premium"]
    assert st["state"] == "warn"
    assert st["metrics"]["e2e"]["burn"]["60s"] == pytest.approx(2.0)
    mon3 = _mon()
    for i in range(100):                     # all violating -> page
        mon3.observe("premium", float(i) * 0.1, e2e=99.0)
    assert mon3.status()["premium"]["state"] == "page"


def test_slo_multi_window_and_resists_blips():
    """A recent burst of violations pages only if the long window
    agrees — the classic multi-window AND."""
    mon = _mon()
    for i in range(50):                      # 500s of healthy traffic
        mon.observe("premium", float(i) * 10.0, e2e=5.0)
    for i in range(20):                      # then a 20-request blip
        mon.observe("premium", 500.0 + i * 0.1, e2e=99.0)
    st = mon.status()["premium"]["metrics"]["e2e"]
    assert st["burn"]["60s"] >= 6.0          # short window is on fire
    assert st["burn"]["600s"] < 6.0          # long window says blip
    assert mon.status()["premium"]["state"] != "page"


def test_slo_monitor_consumes_complete_events():
    mon = _mon()
    rec = TraceRecorder(observers=(mon,))
    rec.emit(1.0, tr.COMPLETE, req_id=1, tenant="premium",
             ttft=5.0, e2e=99.0)
    rec.emit(1.1, tr.COMPLETE, req_id=2, tenant="unknown_tier",
             ttft=5.0, e2e=99.0)             # no target: ignored
    rec.emit(1.2, tr.ARRIVE, req_id=3, tenant="premium")
    st = mon.status()["premium"]
    assert st["metrics"]["ttft"]["n"] == 1
    assert st["state"] == "page"             # 1/1 violating both windows


def test_slo_target_validation():
    with pytest.raises(ValueError):
        SloTarget(ttft=1.0, e2e=1.0, attainment=1.0)
    with pytest.raises(ValueError):
        SloMonitor(windows=())


# --- lifecycle grammar -------------------------------------------------

def _ev(seq, ts, kind, req_id=1, **data):
    return TraceEvent(seq=seq, ts=ts, kind=kind, req_id=req_id, data=data)


def test_validate_accepts_wellformed_chain():
    evs = [_ev(0, 0.0, tr.ARRIVE), _ev(1, 0.0, tr.ADMIT),
           _ev(2, 0.1, tr.ROUTE), _ev(3, 0.2, tr.PREFILL_CHUNK),
           _ev(4, 0.3, tr.FIRST_TOKEN), _ev(5, 0.4, tr.DECODE_STEP),
           _ev(6, 0.5, tr.COMPLETE)]
    assert validate_lifecycles(evs) == []


def test_validate_catches_violations():
    # starts without arrive
    assert validate_lifecycles([_ev(0, 0.0, tr.ADMIT),
                                _ev(1, 0.1, tr.SHED)])
    # events after terminal
    assert validate_lifecycles([_ev(0, 0.0, tr.ARRIVE),
                                _ev(1, 0.1, tr.ADMIT),
                                _ev(2, 0.2, tr.COMPLETE),
                                _ev(3, 0.3, tr.DECODE_STEP)])
    # complete without admit
    assert validate_lifecycles([_ev(0, 0.0, tr.ARRIVE),
                                _ev(1, 0.1, tr.COMPLETE)])
    # timestamp regression
    assert validate_lifecycles([_ev(0, 1.0, tr.ARRIVE),
                                _ev(1, 0.5, tr.ADMIT),
                                _ev(2, 1.1, tr.COMPLETE)])
    # unterminated chain (only with require_terminal)
    open_chain = [_ev(0, 0.0, tr.ARRIVE), _ev(1, 0.1, tr.ADMIT)]
    assert validate_lifecycles(open_chain)
    assert validate_lifecycles(open_chain, require_terminal=False) == []
    # execution before the first route (route-ful stream)
    assert validate_lifecycles([
        _ev(0, 0.0, tr.ARRIVE), _ev(1, 0.0, tr.ADMIT),
        _ev(2, 0.1, tr.PREFILL_CHUNK), _ev(3, 0.2, tr.ROUTE),
        _ev(4, 0.3, tr.COMPLETE)])


def test_validate_prefill_after_first_token_needs_reset():
    bad = [_ev(0, 0.0, tr.ARRIVE), _ev(1, 0.0, tr.ADMIT),
           _ev(2, 0.1, tr.FIRST_TOKEN), _ev(3, 0.2, tr.PREFILL_CHUNK),
           _ev(4, 0.3, tr.COMPLETE)]
    assert validate_lifecycles(bad)
    ok = [_ev(0, 0.0, tr.ARRIVE), _ev(1, 0.0, tr.ADMIT),
          _ev(2, 0.1, tr.FIRST_TOKEN),
          _ev(3, 0.15, tr.PREEMPT, reason="worker_fail"),
          _ev(4, 0.2, tr.PREFILL_CHUNK), _ev(5, 0.3, tr.COMPLETE)]
    assert validate_lifecycles(ok) == []


# --- worker simulator: full-fidelity trace + bit-identity --------------

def _sim_run(trace=None, seed=1):
    gen = WorkloadGenerator(GeneratorConfig(
        total_requests=150, calibration_requests=50, seed=seed))
    plan = gen.plan(seed=seed)
    sched = DriftScheduler(policy="sjf", config=DriftConfig())
    sim = WorkerSimulator(sched, plan,
                          SimConfig(seed=seed, step_engine=True,
                                    chunk_prefill_tokens=256),
                          trace=trace)
    return sched, sim.run()


def _completion_tuples(sched):
    # req_ids come from a process-global counter, so they differ across
    # in-process runs; identity is over the physics, not the ids
    return [(r.completion_time, r.observed_output_tokens, r.tenant.label)
            for r in sched.completed]


def test_sim_trace_lifecycles_valid():
    rec = TraceRecorder(sample_every=FULL)
    sched, m = _sim_run(trace=rec)
    evs = rec.events()
    assert rec.stats()["dropped_overflow"] == 0
    assert validate_lifecycles(evs) == []
    kinds = {e.kind for e in evs}
    assert {"arrive", "admit", "prefill_chunk", "first_token",
            "decode_step", "complete", "drift", "gauge"} <= kinds
    completes = [e for e in evs if e.kind == tr.COMPLETE]
    assert len(completes) == m.n_completed == 150
    # COMPLETE payloads carry the honest latency anchors
    for e in completes:
        assert e.data["e2e"] >= e.data["ttft"] > 0


def test_sim_traced_identical_to_untraced():
    sched_a, m_a = _sim_run(trace=None)
    rec = TraceRecorder(sample_every=FULL)
    sched_b, m_b = _sim_run(trace=rec)
    assert rec.stats()["emitted"] > 0
    assert _completion_tuples(sched_a) == _completion_tuples(sched_b)
    assert m_a.as_dict() == m_b.as_dict()


def test_sim_observers_match_exact_metrics():
    bank = SeriesBank(window=1e9)            # window spans the whole run
    rec = TraceRecorder(sample_every={"decode_step": 64}, observers=(bank,))
    sched, m = _sim_run(trace=rec)
    snap = bank.snapshot()
    # streaming aggregates are exact despite ring thinning: the
    # observer saw every emission pre-sampling
    assert snap["e2e"]["n"] == m.n_completed
    assert snap["e2e"]["mean"] == pytest.approx(m.e2e.mean)
    # step-engine runs anchor TTFT for every request
    assert snap["ttft"]["n"] == m.n_completed
    # P² percentile within the documented 5%-of-range bound
    exact = [r.completion_time - r.arrival_time for r in sched.completed]
    bound = 0.05 * (max(exact) - min(exact))
    assert abs(snap["e2e"]["p95"] - percentile(exact, 95)) <= bound


# --- cluster simulator: full-feature trace + bit-identity --------------

def _cluster_run(trace=None, seed=2):
    gen = WorkloadGenerator(cluster_stress_config(4, seed=seed,
                                                  total_requests=300))
    plan = gen.plan(seed=seed)
    cfg = ClusterConfig(n_replicas=4, routing="pd_disaggregated",
                        step_engine=True, chunk_prefill_tokens=256,
                        work_stealing=True, fail_events=((5.0, 1),),
                        seed=seed)
    sim = ClusterSimulator(
        plan=plan, config=cfg, cost_model=L4_MAX_DRIVEN,
        admission=GlobalAdmission(),
        autoscaler=RoleAutoscaler(RoleAutoscalerConfig(max_replicas=6)),
        trace=trace)
    metrics = sim.run()
    done = []
    for rep in sim.replicas:
        done.extend(rep.sched.completed)
    done.sort(key=lambda r: (r.completion_time, r.observed_output_tokens))
    return sim, metrics, [(r.completion_time, r.observed_output_tokens,
                           r.tenant.label) for r in done]


def test_cluster_trace_lifecycles_valid_under_full_fire():
    """P/D disaggregation + work stealing + replica failure + admission
    + role autoscaling all emitting at once: every surviving chain must
    still parse as a legal lifecycle."""
    rec = TraceRecorder(sample_every=FULL)
    sim, metrics, _ = _cluster_run(trace=rec)
    evs = rec.events()
    assert rec.stats()["dropped_overflow"] == 0
    assert validate_lifecycles(evs) == []
    kinds = {e.kind for e in evs}
    assert {"arrive", "admit", "route", "handoff", "complete",
            "replica_fail", "replica_recover"} <= kinds
    # every handoff 'in' has a replica id; cluster-scope events don't
    for e in evs:
        if e.kind == tr.HANDOFF and e.data.get("edge") == "in":
            assert e.rid is not None
        if e.kind in (tr.SCALE_UP, tr.SCALE_DOWN):
            assert e.req_id is None


def test_cluster_traced_identical_to_untraced():
    _, m_a, tuples_a = _cluster_run(trace=None)
    rec = TraceRecorder(sample_every=FULL)
    _, m_b, tuples_b = _cluster_run(trace=rec)
    assert rec.stats()["emitted"] > 0
    assert tuples_a == tuples_b
    assert m_a.as_dict() == m_b.as_dict()


# --- live JAX engine: trace + bit-identity -----------------------------

def _engine_run(trace=None, seed=0):
    import jax

    from repro.configs import smoke_config
    from repro.models.registry import get_api
    from repro.serving.engine import EngineConfig, ServingEngine
    cfg = smoke_config("smollm-135m")
    api = get_api(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    sched = DriftScheduler(policy="fifo")
    eng = ServingEngine(cfg, params, sched,
                        EngineConfig(n_slots=3, max_len=96,
                                     prompt_buckets=(16,),
                                     chunk_prefill_tokens=8),
                        trace=trace)
    gen = WorkloadGenerator(GeneratorConfig(
        total_requests=8, calibration_requests=8,
        max_tokens=24, seed=seed))
    for t, r in gen.plan(seed=seed).calibration:
        if trace is not None and trace.enabled:
            # front-door events belong to whoever feeds the scheduler
            # (the cluster driver in production, this harness here);
            # ts 0.0 because the standalone engine clock starts there
            trace.emit(0.0, tr.ARRIVE, req_id=r.req_id,
                       tenant=r.tenant.label)
            trace.emit(0.0, tr.ADMIT, req_id=r.req_id,
                       tenant=r.tenant.label)
        sched.submit(r, t)
    m = eng.run_until_drained(max_steps=5000)
    return sched, m


def test_engine_trace_lifecycles_valid():
    rec = TraceRecorder(sample_every=FULL)
    sched, m = _engine_run(trace=rec)
    evs = rec.events()
    assert validate_lifecycles(evs) == []
    assert sum(e.kind == tr.COMPLETE for e in evs) == m.n_completed == 8
    assert any(e.kind == tr.PREFILL_CHUNK for e in evs)
    assert any(e.kind == tr.FIRST_TOKEN for e in evs)
    assert rec.stats()["segments"] == ["engine:fifo"]


def test_engine_traced_identical_to_untraced():
    sched_a, m_a = _engine_run(trace=None)
    rec = TraceRecorder(sample_every=FULL)
    sched_b, m_b = _engine_run(trace=rec)
    assert rec.stats()["emitted"] > 0
    assert _completion_tuples(sched_a) == _completion_tuples(sched_b)
    assert m_a.as_dict() == m_b.as_dict()


# --- timeline export ---------------------------------------------------

def test_chrome_trace_export_validates_and_pairs_flows():
    rec = TraceRecorder(sample_every=FULL)
    _cluster_run(trace=rec)
    doc = to_chrome_trace(rec.events(), recorder_stats=rec.stats())
    assert validate_chrome_trace(doc) == []
    evs = doc["traceEvents"]
    phases = {e["ph"] for e in evs}
    assert {"M", "X", "i", "C", "s", "f"} <= phases
    n_s = sum(e["ph"] == "s" for e in evs)
    n_f = sum(e["ph"] == "f" for e in evs)
    assert n_s == n_f > 0                   # P/D handoffs drew arrows
    # one lifetime slice per completed/shed request
    lifetimes = [e for e in evs if e["ph"] == "X"
                 and e.get("args", {}).get("kind") == "lifetime"]
    assert lifetimes and all(e["dur"] >= 0 for e in lifetimes)
    # process metadata names segment/replica tracks
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert any("replica" in n for n in names)
    assert doc["otherData"]["recorder"]["emitted"] > 0


def test_validate_chrome_trace_catches_breakage():
    assert validate_chrome_trace({}) == ["traceEvents missing or not a list"]
    base = {"name": "x", "ph": "X", "ts": 0, "pid": 1, "tid": 1, "dur": 1}
    assert validate_chrome_trace({"traceEvents": [dict(base, dur=-5)]})
    assert validate_chrome_trace({"traceEvents": [{"ph": "X"}]})
    # ts regression on one track
    assert validate_chrome_trace({"traceEvents": [
        dict(base, ts=10), dict(base, ts=5)]})
    # unbalanced flow
    assert validate_chrome_trace({"traceEvents": [
        {"name": "h", "ph": "s", "ts": 0, "pid": 1, "tid": 1, "id": 9}]})


def test_write_trace_and_report_cli(tmp_path, capsys):
    from repro.obs import report
    rec = TraceRecorder(sample_every=FULL)
    _sim_run(trace=rec)
    path = str(tmp_path / "trace.json")
    doc = write_chrome_trace(path, rec.events(), recorder_stats=rec.stats())
    assert validate_chrome_trace(doc) == []
    with open(path) as f:
        assert validate_chrome_trace(json.load(f)) == []
    assert report.main([path]) == 0
    out = capsys.readouterr().out
    assert f"trace OK: {path}" in out
    assert "recorder: emitted=" in out
    # missing / corrupt / structurally invalid files fail loudly
    assert report.main([str(tmp_path / "nope.json")]) == 2
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert report.main([str(bad)]) == 2
    invalid = tmp_path / "invalid.json"
    invalid.write_text(json.dumps({"traceEvents": [{"ph": "X"}]}))
    assert report.main([str(invalid)]) == 1


# --- JSON sanitization (silent-NaN footgun) ----------------------------

def test_sanitize_json_nan_to_null_everywhere():
    from benchmarks.common import sanitize_json

    @dataclass
    class Payload:
        p50: float
        nested: dict

    obj = {
        "direct": float("nan"),
        "inf": float("inf"),
        "list": [1.0, float("nan"), 3.0],
        "dc": Payload(p50=float("nan"), nested={"x": float("-inf")}),
        "fine": 1.5,
    }
    out = sanitize_json(obj)
    assert out["direct"] is None and out["inf"] is None
    assert out["list"] == [1.0, None, 3.0]
    assert out["dc"] == {"p50": None, "nested": {"x": None}}
    assert out["fine"] == 1.5
    # strict JSON round-trip: no bare literals, no stringified NaNs
    text = json.dumps(out, allow_nan=False, default=str)
    for leak in ('"nan"', "NaN", "Infinity"):
        assert leak not in text


def test_sanitize_json_unpacks_numpy_before_nan_check():
    np = pytest.importorskip("numpy")
    from benchmarks.common import sanitize_json
    obj = {"scalar": np.float64("nan"), "arr": np.array([1.0, float("nan")]),
           "int": np.int64(7)}
    out = sanitize_json(obj)
    assert out["scalar"] is None
    assert out["arr"] == [1.0, None]
    assert out["int"] == 7
    text = json.dumps(out, allow_nan=False, default=str)
    assert "nan" not in text.lower()


def test_empty_latency_stats_sanitizes_to_null():
    """The exact footgun this PR fixes: an empty LatencyStats used to
    reach json.dump(default=str) as a dataclass full of NaNs and come
    out as the string \"nan\"."""
    from benchmarks.common import sanitize_json
    from repro.serving.metrics import LatencyStats
    empty = LatencyStats.of([])
    out = sanitize_json({"ttft": empty})
    assert out["ttft"]["p50"] is None
    assert "nan" not in json.dumps(out, allow_nan=False).lower()


# --- shared stats helpers (satellite: single source of truth) ----------

def test_metrics_reexports_obs_stats():
    from repro.obs import stats as obs_stats
    from repro.serving import metrics
    assert metrics.percentile is obs_stats.percentile
    assert metrics.jain_index is obs_stats.jain_index
    assert metrics.LatencyStats is obs_stats.LatencyStats
