"""Variant sharding layouts lower correctly (single-device smoke of the
§Perf code paths: dp-all batch mode, replicated / serve-2d params,
logical-rule context switching)."""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.distributed.sharding import (DP_ALL_RULES, LOGICAL_RULES,
                                        logical_mode, logical_to_spec)
from repro.launch import cell_shardings as cs
from repro.models.registry import abstract_params


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_dp_all_rules_spread_batch_over_model():
    mesh = _FakeMesh({"data": 16, "model": 16})
    with logical_mode("dp-all"):
        spec = logical_to_spec(["batch", None, "model"], (256, 4, 4096),
                               mesh)
    assert spec == P(("data", "model"), None, None)
    # and the context restores the default rules
    spec2 = logical_to_spec(["batch", None, "model"], (256, 4, 4096), mesh)
    assert spec2 == P("data", None, "model")


def test_params_modes_resolve():
    cfg = smoke_config("grok-1-314b")
    aparams = abstract_params(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for mode in ("train", "serve", "replicated", "serve-2d"):
        shard, policy = cs.params_shardings_for(cfg, mesh, aparams,
                                                mode=mode)
        assert len(jax.tree_util.tree_leaves(shard)) == \
            len(jax.tree_util.tree_leaves(aparams))
        assert isinstance(policy, str) and policy


def test_serve_2d_replicates_on_trivial_mesh():
    """Size-1 mesh axes are never named (divisibility guard); the
    sharded 256-chip behaviour is exercised by the dry-run probes."""
    cfg = smoke_config("minitron-8b")
    aparams = abstract_params(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shard = cs._params_2d(cfg, mesh, aparams)
    for s in jax.tree_util.tree_leaves(shard):
        assert all(p is None for p in s.spec)


def test_serve_2d_spec_logic_on_16x16_shapes():
    """Pure spec arithmetic for the production mesh sizes."""
    sizes = {"data": 16, "model": 16}
    # grok mlp w1 [L=64, d=6144, ff=32768]: 6144%16==0, 32768%16==0
    assert 6144 % sizes["data"] == 0 and 32768 % sizes["model"] == 0
    # whisper heads 20 % 16 != 0 -> head_dim 64 % 16 == 0 fallback
    assert 20 % sizes["model"] != 0 and 64 % sizes["model"] == 0


def test_variant_cells_lower_on_tiny_mesh():
    """lower_cell with every variant knob on a 1x1 mesh (CPU) — the same
    code path the 256-chip probes exercise."""
    from repro.launch.dryrun import lower_cell
    from repro.configs.shapes import SHAPES, Shape, input_specs
    import repro.configs.shapes as shapes_mod

    cfg = smoke_config("smollm-135m")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tiny = Shape("tiny_train", 32, 4, "train")
    SHAPES["tiny_train"] = tiny
    try:
        for kw in (dict(),
                   dict(batch_mode="dp-all", param_mode="replicated"),
                   dict(param_mode="serve-2d"),
                   dict(remat=False)):
            with mesh:
                lowered, meta = lower_cell(cfg, "tiny_train", mesh, **kw)
                assert lowered.compile() is not None, kw
    finally:
        del SHAPES["tiny_train"]
