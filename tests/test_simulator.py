"""Cluster-simulator behaviour: paper protocol, policy orderings,
fault tolerance, straggler mitigation."""

import pytest

from repro.core.estimator import DriftConfig
from repro.core.scheduler import DriftScheduler
from repro.core.drift import error_reduction
from repro.serving.simulator import SimConfig, WorkerSimulator
from repro.workload.generator import GeneratorConfig, WorkloadGenerator

# small runs keep the suite fast; the full 3000-request protocol runs in
# benchmarks/
SMALL = GeneratorConfig(total_requests=400, calibration_requests=130, seed=7)


def _run(policy="fifo", bias=True, sim_cfg=None, gen_cfg=SMALL, seed=7):
    plan = WorkloadGenerator(gen_cfg).plan(seed=seed)
    sched = DriftScheduler(policy=policy,
                           config=DriftConfig(bias_enabled=bias))
    sim = WorkerSimulator(sched, plan, sim_cfg or SimConfig(seed=seed))
    metrics = sim.run()
    return sched, sim, metrics


def test_all_requests_complete():
    sched, sim, m = _run()
    assert m.n_completed == 400
    assert m.makespan > 0
    assert all(r.completion_time is not None for r in sched.completed)


def test_two_phase_protocol():
    """Stress burst is released only after calibration drains."""
    sched, sim, m = _run()
    assert sim.phase_boundary > 0
    cal_completions = [r.completion_time for r in sched.completed[:130]]
    # the 130 calibration requests all complete before the boundary
    assert max(cal_completions) <= sim.phase_boundary + 1e-9


def test_sjf_beats_fifo_on_wait_and_p50():
    _, _, fifo = _run("fifo")
    _, _, sjf = _run("sjf")
    assert sjf.queue_wait.mean < 0.8 * fifo.queue_wait.mean
    assert sjf.e2e.p50 < 0.7 * fifo.e2e.p50


def test_priority_protects_premium():
    _, _, m = _run("priority")
    prem = m.per_tenant["premium"]["latency"]["mean"]
    batch = m.per_tenant["batch"]["latency"]["mean"]
    assert prem < 0.5 * batch


def test_sjf_orders_waits_by_class():
    _, _, m = _run("sjf")
    w = m.per_class_wait
    assert w["short"] < w["medium"] < w["long"]


def test_gpu_utilization_saturated():
    _, _, m = _run()
    assert m.gpu_utilization > 0.8


def test_drift_compensation_reduces_error():
    s_on, _, _ = _run("fifo", bias=True)
    s_off, _, _ = _run("fifo", bias=False)
    red = error_reduction(s_off.drift.stats(), s_on.drift.stats())
    assert red["mae_reduction_pct"] > 15.0
    assert red["rmse_reduction_pct"] > 15.0


def test_bias_converges_into_band():
    sched, _, _ = _run("fifo", bias=True)
    for cat, b in sched.bias_store.snapshot().items():
        assert 0.70 <= b <= 0.92, (cat, b)


def test_worker_failure_requeues_and_completes():
    cfg = SimConfig(seed=7, fail_times=(15.0, 90.0), repair_time=20.0)
    sched, sim, m = _run(sim_cfg=cfg)
    assert m.n_completed == 400                 # nothing lost
    assert m.n_failed_dispatches > 0            # failures actually hit
    retried = [r for r in sched.completed if r.retries > 0]
    assert retried                               # and were retried
    # at-most-once feedback: updates == completions
    assert sum(sched.bias_store.update_counts().values()) == 400


def test_failure_does_not_double_feed_bias():
    cfg = SimConfig(seed=7, fail_times=(15.0,), repair_time=5.0)
    sched, _, _ = _run(sim_cfg=cfg)
    assert sum(sched.bias_store.update_counts().values()) == len(sched.completed)


def test_multi_worker_scales_throughput():
    _, _, one = _run(sim_cfg=SimConfig(seed=7, n_workers=1))
    _, _, four = _run(sim_cfg=SimConfig(seed=7, n_workers=4))
    assert four.makespan < 0.5 * one.makespan


def test_straggler_mitigation_helps():
    slow = SimConfig(seed=7, n_workers=2, straggler_worker=1,
                     straggler_after=5.0, straggler_factor=8.0)
    mit = SimConfig(seed=7, n_workers=2, straggler_worker=1,
                    straggler_after=5.0, straggler_factor=8.0,
                    mitigate_stragglers=True)
    _, sim_a, a = _run(sim_cfg=slow)
    _, sim_b, b = _run(sim_cfg=mit)
    assert sim_b.stragglers.stragglers() == [1]
    assert b.e2e.p99 < a.e2e.p99


def test_telemetry_sampled():
    _, sim, m = _run()
    assert len(sim.telemetry) > 100
    busy = [t for t in sim.telemetry if t.gpu_util > 0.5]
    assert busy
    assert all(13.5 < t.gpu_mem_gb < 15.5 for t in busy)


def test_determinism():
    _, _, a = _run(seed=11)
    _, _, b = _run(seed=11)
    assert a.e2e.p99 == b.e2e.p99
    assert a.queue_wait.mean == b.queue_wait.mean


def test_hedged_dispatch_rescues_straggling_batches():
    """Batch-level speculative re-execution: a slowed worker's overdue
    batches re-run on idle workers; first completion wins, nothing is
    completed twice, and tail latency improves."""
    base = SimConfig(seed=7, n_workers=3, straggler_worker=2,
                     straggler_after=5.0, straggler_factor=10.0)
    hedged = SimConfig(seed=7, n_workers=3, straggler_worker=2,
                       straggler_after=5.0, straggler_factor=10.0,
                       hedge=True, hedge_factor=2.0)
    sched_a, sim_a, a = _run(sim_cfg=base)
    sched_b, sim_b, b = _run(sim_cfg=hedged)
    assert sim_b.n_hedges > 0
    assert sim_b.n_hedge_wins > 0
    assert b.n_completed == 400
    # exactly-once completion feedback despite duplicate execution
    assert sum(sched_b.bias_store.update_counts().values()) == 400
    assert b.e2e.p99 < a.e2e.p99
