"""Iteration-level execution core: step-time decomposition, atomic
parity, token conservation, chunked-prefill TTFT behaviour, mid-flight
joins, iteration-boundary preemption, and the cluster threading."""

from dataclasses import replace

import pytest

from repro.cluster import ClusterConfig, ClusterSimulator
from repro.core.estimator import DriftConfig
from repro.core.request import Category, Request, TenantTier
from repro.core.scheduler import DriftScheduler
from repro.serving.cost_model import L4_MAX_DRIVEN, L4_QWEN_1_8B
from repro.serving.simulator import SimConfig, WorkerSimulator
from repro.workload.generator import (GeneratorConfig, WorkloadGenerator,
                                      cluster_stress_config)

# zero-jitter calibrations: the parity/monotonicity properties are about
# the execution-model decomposition, not the lognormal noise on top
NOJIT_SUM = replace(L4_QWEN_1_8B, jitter_sigma=0.0)
NOJIT_MAX = replace(L4_MAX_DRIVEN, jitter_sigma=0.0)

# long-prompt stress traffic (RAG/agent scale) — the regime where
# per-iteration prefill budgets have teeth
STRESS = GeneratorConfig(total_requests=240, calibration_requests=80,
                         seed=7, prompt_tokens_scale=16.0)


def _run(*, step_engine, joins=True, chunk=None, cost=NOJIT_SUM,
         gen_cfg=STRESS, seed=7, policy="fifo", **sim_kw):
    plan = WorkloadGenerator(gen_cfg).plan(seed=seed)
    sched = DriftScheduler(policy=policy, config=DriftConfig())
    sim = WorkerSimulator(
        sched, plan,
        SimConfig(seed=seed, step_engine=step_engine,
                  continuous_joins=joins, chunk_prefill_tokens=chunk,
                  **sim_kw),
        cost_model=cost)
    return sched, sim, sim.run()


# --- cost model: step_time is the primitive, batch_time the view -------

def _decomposed_batch_time(cost, reqs):
    """Sum step_time over the iterations of an atomic batch run: every
    prompt prefills in iteration 1, slot i emits in iterations
    1..out_i."""
    outs = sorted(min(r.true_output_tokens, r.max_tokens) for r in reqs)
    total = cost.step_time(len(outs), sum(r.prompt_tokens for r in reqs),
                           include_base=True)
    prev = 0
    alive = len(outs)
    for i, out in enumerate(outs):
        # iterations prev+1..out run with `alive` emitting slots; the
        # first iteration was already priced above
        span = out - max(prev, 1) if prev == 0 else out - prev
        if span > 0:
            total += span * cost.step_time(alive)
        prev = max(prev, out)
        alive -= 1
    return total


@pytest.mark.parametrize("cost", [NOJIT_SUM, NOJIT_MAX],
                         ids=["sum_dominated", "batch_walk"])
def test_batch_time_is_telescoped_step_time(cost):
    plan = WorkloadGenerator(STRESS).plan(seed=3)
    reqs = [r for _, r in plan][:32]
    assert _decomposed_batch_time(cost, reqs) == pytest.approx(
        cost.batch_time(reqs), rel=1e-9)
    # singleton + empty edge cases
    assert _decomposed_batch_time(cost, reqs[:1]) == pytest.approx(
        cost.batch_time(reqs[:1]), rel=1e-9)
    assert cost.batch_time([]) == 0.0
    assert cost.step_time(0, 0) == 0.0


# --- parity: step engine degenerates to the atomic contract ------------

@pytest.mark.parametrize("cost", [NOJIT_SUM, NOJIT_MAX],
                         ids=["sum_dominated", "batch_walk"])
def test_parity_mode_reproduces_atomic_batches(cost):
    """chunk budget = inf + joins off must reproduce the legacy
    atomic-batch e2e latencies (exactly, modulo float summation order:
    jitter is zeroed so the only difference is per-iteration vs
    closed-form pricing)."""
    sa, xa, ma = _run(step_engine=False, cost=cost)
    sb, xb, mb = _run(step_engine=True, joins=False, chunk=None, cost=cost)
    assert ma.n_completed == mb.n_completed == 240
    # req_ids are a process-global counter: align the two runs by their
    # per-run ordering (plans are generated identically)
    ea = [lat for _, lat in sorted((r.req_id, r.e2e_latency)
                                   for r in sa.completed)]
    eb = [lat for _, lat in sorted((r.req_id, r.e2e_latency)
                                   for r in sb.completed)]
    assert ea == pytest.approx(eb, rel=1e-9)
    ga = [lat for _, lat in sorted((r.req_id, r.gpu_latency)
                                   for r in sa.completed)]
    gb = [lat for _, lat in sorted((r.req_id, r.gpu_latency)
                                   for r in sb.completed)]
    assert ga == pytest.approx(gb, rel=1e-9)
    assert ma.gpu_utilization == pytest.approx(mb.gpu_utilization, rel=1e-9)


def test_parity_mode_close_under_jitter():
    """With the default lognormal jitter the two paths consume rng
    differently (per-step vs per-batch draws), but the distributions
    must stay within jitter tolerance."""
    _, _, ma = _run(step_engine=False, cost=L4_QWEN_1_8B)
    _, _, mb = _run(step_engine=True, joins=False, chunk=None,
                    cost=L4_QWEN_1_8B)
    assert mb.e2e.p50 == pytest.approx(ma.e2e.p50, rel=0.05)
    assert mb.e2e.mean == pytest.approx(ma.e2e.mean, rel=0.05)


# --- token accounting conservation -------------------------------------

@pytest.mark.parametrize("chunk", [None, 512, 64],
                         ids=["inf", "512", "64"])
def test_token_accounting_conserves(chunk):
    """Per-step prefill + decode emissions must sum to exactly each
    request's prompt + observed output — chunking reschedules tokens,
    never creates or drops them."""
    sched, sim, m = _run(step_engine=True, joins=True, chunk=chunk)
    assert m.n_completed == 240
    for r in sched.completed:
        assert sim.token_ledger[r.req_id] == \
            [r.prompt_tokens, r.observed_output_tokens]
    # observed == planned oracle length on the failure-free path
    assert all(r.observed_output_tokens ==
               min(r.true_output_tokens, r.max_tokens)
               for r in sched.completed)


# --- TTFT behaviour ----------------------------------------------------

def test_step_engine_reports_real_ttft():
    """Unified replicas on the step engine anchor TTFT at the iteration
    that emitted the first token — strictly before batch-drain e2e."""
    sched, sim, m = _run(step_engine=True, joins=True, chunk=512)
    assert all(r.prefill_end is not None for r in sched.completed)
    assert all(r.ttft <= r.e2e_latency + 1e-12 for r in sched.completed)
    mean_ttft = sum(r.ttft for r in sched.completed) / 240
    assert mean_ttft < 0.8 * m.e2e.mean
    assert sim.n_joins > 0           # mid-flight admission actually ran


@pytest.mark.parametrize("cost", [NOJIT_SUM, NOJIT_MAX],
                         ids=["sum_dominated", "batch_walk"])
def test_ttft_monotone_in_chunk_budget(cost):
    """Down to the per-iteration overhead floor (~c_decode_max /
    c_prefill tokens), a smaller chunk budget never worsens mean TTFT
    under the bursty long-prompt stress workload: serialized prefill
    chunks mean early joiners stop waiting for the whole wave's
    prompts. (Below the floor the extra iteration walk overhead
    dominates — bench_chunked_prefill shows the full U-shape.)"""
    burst = GeneratorConfig(total_requests=128, calibration_requests=32,
                            calibration_rate=200.0, stress_rate=200.0,
                            seed=11, prompt_tokens_scale=32.0)
    means = []
    for chunk in (None, 8192, 4096, 2048):
        sched, _, m = _run(step_engine=True, joins=True, chunk=chunk,
                           cost=cost, gen_cfg=burst, seed=11)
        assert m.n_completed == 128
        means.append(sum(r.ttft for r in sched.completed) / 128)
    for wider, tighter in zip(means, means[1:]):
        assert tighter <= wider * (1 + 1e-9), means


# --- joins, preemption, scheduler knob ---------------------------------

def test_continuous_joins_beat_atomic_batches_end_to_end():
    _, _, atomic = _run(step_engine=False, cost=NOJIT_MAX)
    _, sim, cont = _run(step_engine=True, joins=True, chunk=None,
                        cost=NOJIT_MAX)
    assert cont.n_completed == atomic.n_completed == 240
    assert sim.n_joins > 0
    # freed slots refill instead of walking to the batch's longest
    # member: strictly better median e2e in the batch-walk regime
    assert cont.e2e.p50 < atomic.e2e.p50


def test_step_engine_failure_preempts_at_iteration_boundary():
    sched, sim, m = _run(step_engine=True, joins=True, chunk=512,
                         fail_times=(10.0, 60.0), repair_time=15.0)
    assert m.n_completed == 240                  # nothing lost
    assert m.n_failed_dispatches > 0             # the abort actually hit
    retried = [r for r in sched.completed if r.retries > 0]
    assert retried
    # at-most-once drift feedback despite preemption + retries
    assert sum(sched.bias_store.update_counts().values()) == 240
    # conservation still holds: aborted iterations were discarded and
    # the retry re-ran from scratch
    for r in sched.completed:
        assert sim.token_ledger[r.req_id] == \
            [r.prompt_tokens, r.observed_output_tokens]


def test_max_new_per_step_caps_iteration_admission():
    sched = DriftScheduler(policy="fifo", max_new_per_step=2)
    for i in range(8):
        sched.submit(Request(tenant=TenantTier.STANDARD,
                             category=Category.SHORT_QA,
                             prompt="what is dns"), now=0.0)
    assert len(sched.dispatch_step(0.0, 6)) == 2    # knob binds
    assert len(sched.dispatch_step(0.0, 1)) == 1    # free slots bind
    uncapped = DriftScheduler(policy="fifo")
    for i in range(4):
        uncapped.submit(Request(tenant=TenantTier.STANDARD,
                                category=Category.SHORT_QA,
                                prompt="what is dns"), now=0.0)
    assert len(uncapped.dispatch_step(0.0, 8)) == 4
    with pytest.raises(ValueError):
        DriftScheduler(policy="fifo", max_new_per_step=0)


def test_step_engine_rejects_hedge_and_bad_chunk():
    sched = DriftScheduler()
    with pytest.raises(ValueError):
        WorkerSimulator(sched, config=SimConfig(step_engine=True,
                                                hedge=True))
    with pytest.raises(ValueError):
        WorkerSimulator(sched, config=SimConfig(step_engine=True,
                                                chunk_prefill_tokens=0))
    # a chunk budget on the atomic path would be silently ignored —
    # refused instead of misread as "chunking has no effect"
    with pytest.raises(ValueError, match="step_engine"):
        WorkerSimulator(sched, config=SimConfig(step_engine=False,
                                                chunk_prefill_tokens=512))


# --- telemetry memory model --------------------------------------------

def test_memory_telemetry_tracks_kv_occupancy():
    """gpu_mem_gb = plateau + workspace scaled by paged-KV occupancy:
    it must move with load (not the old constant-per-fill formula) and
    stay on the paper's observed plateau band."""
    _, sim, _ = _run(step_engine=True, joins=True, chunk=512)
    busy = [t.gpu_mem_gb for t in sim.telemetry if t.gpu_util > 0.5]
    assert busy
    assert all(13.5 < m_ < 15.5 for m_ in busy)
    assert max(busy) - min(busy) > 0.01          # occupancy moves it
    idle = [t.gpu_mem_gb for t in sim.telemetry if t.gpu_util <= 0.5]
    if idle:
        assert all(m_ == pytest.approx(14.0) for m_ in idle)


# --- cluster threading --------------------------------------------------

def _cluster_run(seed=1, n=4, total=300, **cfg_kw):
    cfg = ClusterConfig(n_replicas=n, seed=seed, step_engine=True,
                        **cfg_kw)
    gen = WorkloadGenerator(cluster_stress_config(
        n, seed=seed, total_requests=total, prompt_tokens_scale=8.0))
    sim = ClusterSimulator(plan=gen.plan(seed=seed), config=cfg,
                           cost_model=L4_MAX_DRIVEN)
    return sim, sim.run()


def test_cluster_step_engine_unified_honest_ttft():
    sim, m = _cluster_run(routing="least_loaded",
                          chunk_prefill_tokens=512)
    assert m.run.n_completed == 300
    # honest TTFT: strictly below e2e now, not degraded to batch end
    assert m.ttft.p50 < 0.5 * m.run.e2e.p50
    # at-most-once drift feedback across the pool
    assert sum(sim.estimator.bias_store.update_counts().values()) == 300


def test_cluster_step_engine_determinism():
    _, a = _cluster_run(seed=3, routing="least_loaded",
                        chunk_prefill_tokens=256)
    _, b = _cluster_run(seed=3, routing="least_loaded",
                        chunk_prefill_tokens=256)
    assert a.as_dict() == b.as_dict()


def test_cluster_step_engine_pd_contract_survives():
    """P/D on the step engine: handoffs fire per retired prefill slot,
    drift feedback still fires exactly once, attributed to decode."""
    sim, m = _cluster_run(routing="pd_disaggregated",
                          chunk_prefill_tokens=512)
    assert m.run.n_completed == 300
    assert m.n_handoffs == 300
    done = [r for rep in sim.replicas for r in rep.sched.completed]
    assert all(r.prefill_end is not None and r.handoff_time is not None
               and r.prefill_rid != r.decode_rid for r in done)
    assert all(r.ttft < r.e2e_latency for r in done)
    phases = {}
    for rep in sim.replicas:
        for k, v in rep.sched.phase_feedback_counts.items():
            phases[k] = phases.get(k, 0) + v
    assert phases == {"decode": 300}
    assert sum(sim.estimator.bias_store.update_counts().values()) == 300


def test_cluster_step_engine_failure_recovery():
    sim, m = _cluster_run(routing="pd_disaggregated",
                          chunk_prefill_tokens=512,
                          fail_events=((15.0, 2),), repair_time=25.0)
    assert m.run.n_completed == 300
    assert m.n_rerouted > 0
    assert sum(sim.estimator.bias_store.update_counts().values()) == 300


def test_max_new_per_step_threads_through_cluster():
    sim, m = _cluster_run(routing="least_loaded", max_new_per_step=2)
    assert m.run.n_completed == 300
    assert all(rep.sched.max_new_per_step == 2 for rep in sim.replicas)


# --- satellite: the stale serving alias is gone ------------------------

def test_serving_cluster_simulator_alias_removed():
    import repro.serving.simulator as srv_sim
    with pytest.raises(ImportError, match="repro.cluster"):
        srv_sim.ClusterSimulator
    with pytest.raises(ImportError):
        from repro.serving import ClusterSimulator  # noqa: F401
    with pytest.raises(AttributeError):
        srv_sim.definitely_not_a_symbol
