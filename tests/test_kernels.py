"""Pallas kernels vs pure-jnp oracles, executed with interpret=True on
CPU. Shape/dtype sweeps per kernel + chunked-form cross-validation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    batched_paged_decode_attention,
    chunked_prefill_attention,
    flash_attention,
    paged_decode_attention,
    ssd_scan,
)
from repro.kernels import ref
from repro.kernels.paged_attention import safe_page_index


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Lq,Lk,H,Hk,D", [
    (1, 128, 128, 4, 4, 64),       # MHA square
    (2, 128, 128, 4, 2, 32),       # GQA
    (1, 64, 256, 8, 1, 64),        # MQA, decode-style Lq < Lk
    (2, 200, 200, 3, 3, 48),       # ragged (padding path)
])
def test_flash_attention_causal(B, Lq, Lk, H, Hk, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, Lq, H, D), dtype)
    k = _rand(ks[1], (B, Lk, Hk, D), dtype)
    v = _rand(ks[2], (B, Lk, Hk, D), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                          interpret=True)
    expect = ref.mha_naive(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, L, H, Hk, D = 1, 256, 4, 2, 32
    q = _rand(ks[0], (B, L, H, D), jnp.float32)
    k = _rand(ks[1], (B, L, Hk, D), jnp.float32)
    v = _rand(ks[2], (B, L, Hk, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_kv=64, interpret=True)
    expect = ref.mha_naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal_and_softcap():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, L, H, D = 1, 128, 2, 64
    q = _rand(ks[0], (B, L, H, D), jnp.float32)
    k = _rand(ks[1], (B, L, H, D), jnp.float32)
    v = _rand(ks[2], (B, L, H, D), jnp.float32)
    out = flash_attention(q, k, v, causal=False, logit_softcap=30.0,
                          block_q=64, block_kv=64, interpret=True)
    expect = ref.mha_naive(q, k, v, causal=False, logit_softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_prefix_lm():
    """PaliGemma-style: prefix keys visible to all queries."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, L, H, D, P = 1, 128, 2, 32, 16
    q = _rand(ks[0], (B, L, H, D), jnp.float32)
    k = _rand(ks[1], (B, L, H, D), jnp.float32)
    v = _rand(ks[2], (B, L, H, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, prefix_len=P,
                          block_q=64, block_kv=64, interpret=True)
    expect = ref.mha_naive(q, k, v, causal=True, prefix_len=P)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_chunked_reference_matches_naive():
    """The jnp chunked form (what non-TPU backends lower) == naive."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    B, L, H, Hk, D = 2, 160, 4, 2, 32
    q = _rand(ks[0], (B, L, H, D), jnp.float32)
    k = _rand(ks[1], (B, L, Hk, D), jnp.float32)
    v = _rand(ks[2], (B, L, Hk, D), jnp.float32)
    for kw in (dict(causal=True), dict(causal=False),
               dict(causal=True, window=48),
               dict(causal=True, prefix_len=8)):
        got = ref.flash_attention_chunked(q, k, v, block_kv=64, **kw)
        expect = ref.mha_naive(q, k, v, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hk,D,page,pages_per_seq", [
    (2, 4, 2, 64, 16, 8),
    (3, 8, 1, 32, 32, 4),
    (1, 4, 4, 128, 16, 16),
])
def test_paged_decode_attention(B, H, Hk, D, page, pages_per_seq, dtype):
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 4)
    n_pages = B * pages_per_seq + 3
    q = _rand(ks[0], (B, H, D), dtype)
    k_pages = _rand(ks[1], (n_pages, page, Hk, D), dtype)
    v_pages = _rand(ks[2], (n_pages, page, Hk, D), dtype)
    # each sequence gets a random non-overlapping page set
    perm = jax.random.permutation(ks[3], n_pages)[:B * pages_per_seq]
    page_table = perm.reshape(B, pages_per_seq).astype(jnp.int32)
    seq_lens = jnp.array(
        [1 + (7 * i) % (page * pages_per_seq) for i in range(B)], jnp.int32)
    out = paged_decode_attention(q, k_pages, v_pages, page_table, seq_lens,
                                 interpret=True)
    expect = ref.paged_decode_attention_ref(q, k_pages, v_pages, page_table,
                                            seq_lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_paged_equals_contiguous():
    """Paged pool gather == contiguous-cache decode attention."""
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 4)
    B, H, Hk, D, page, pps = 2, 4, 2, 32, 16, 4
    S = page * pps
    q = _rand(ks[0], (B, H, D), jnp.float32)
    k_cache = _rand(ks[1], (B, S, Hk, D), jnp.float32)
    v_cache = _rand(ks[2], (B, S, Hk, D), jnp.float32)
    lens = jnp.array([37, 61], jnp.int32)
    # lay the contiguous cache into pages
    k_pages = k_cache.reshape(B * pps, page, Hk, D)
    v_pages = v_cache.reshape(B * pps, page, Hk, D)
    page_table = jnp.arange(B * pps, dtype=jnp.int32).reshape(B, pps)
    got = ref.paged_decode_attention_ref(q, k_pages, v_pages, page_table, lens)
    expect = ref.decode_attention_ref(q, k_cache, v_cache, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# batched paged decode (whole decode set, fused new-token K/V)
# ---------------------------------------------------------------------------

def _page_scene(key, B, Hk, D, page, pps, dtype, extra=3):
    """Random pool + non-overlapping per-sequence page tables."""
    ks = jax.random.split(key, 3)
    n_pages = B * pps + extra
    k_pages = _rand(ks[0], (n_pages, page, Hk, D), dtype)
    v_pages = _rand(ks[1], (n_pages, page, Hk, D), dtype)
    perm = jax.random.permutation(ks[2], n_pages)[:B * pps]
    page_table = perm.reshape(B, pps).astype(jnp.int32)
    return k_pages, v_pages, page_table


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("max_pages", [None, "trim"])
def test_batched_paged_decode_vs_per_sequence(dtype, max_pages):
    """The batched kernel == scatter-then-per-sequence decode calls."""
    key = jax.random.PRNGKey(10)
    ks = jax.random.split(key, 4)
    B, H, Hk, D, page, pps = 3, 4, 2, 32, 8, 6
    k_pages, v_pages, page_table = _page_scene(ks[0], B, Hk, D, page, pps,
                                               dtype)
    q = _rand(ks[1], (B, H, D), dtype)
    k_new = _rand(ks[2], (B, Hk, D), dtype)
    v_new = _rand(ks[3], (B, Hk, D), dtype)
    seq_lens = jnp.array([5, 17, 29], jnp.int32)
    mp = None if max_pages is None else max(1, -(-29 // page))
    got = batched_paged_decode_attention(
        q, k_pages, v_pages, page_table, seq_lens, k_new, v_new,
        max_pages=mp, interpret=True)
    # per-sequence baseline: scatter the new token, then one legacy
    # kernel call per sequence over seq_lens + 1 tokens
    phys = page_table[jnp.arange(B), seq_lens // page]
    k_sc = k_pages.at[phys, seq_lens % page].set(k_new)
    v_sc = v_pages.at[phys, seq_lens % page].set(v_new)
    for b in range(B):
        single = paged_decode_attention(
            q[b:b + 1], k_sc, v_sc, page_table[b:b + 1],
            seq_lens[b:b + 1] + 1, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got[b], np.float32), np.asarray(single[0], np.float32),
            **_tol(dtype), err_msg=f"seq {b}")
    expect = ref.batched_paged_decode_attention_ref(
        q, k_pages, v_pages, page_table, seq_lens, k_new, v_new)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_batched_paged_decode_softcap():
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 4)
    B, H, Hk, D, page, pps = 2, 8, 2, 16, 8, 4
    k_pages, v_pages, page_table = _page_scene(ks[0], B, Hk, D, page, pps,
                                               jnp.float32)
    q = _rand(ks[1], (B, H, D), jnp.float32)
    k_new = _rand(ks[2], (B, Hk, D), jnp.float32)
    v_new = _rand(ks[3], (B, Hk, D), jnp.float32)
    seq_lens = jnp.array([0, 23], jnp.int32)   # incl. empty pool (first token)
    got = batched_paged_decode_attention(
        q, k_pages, v_pages, page_table, seq_lens, k_new, v_new,
        logit_softcap=30.0, interpret=True)
    expect = ref.batched_paged_decode_attention_ref(
        q, k_pages, v_pages, page_table, seq_lens, k_new, v_new,
        logit_softcap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# fused chunked prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("chunk,cached,group", [
    (16, 0, 1),     # first chunk, MHA
    (16, 16, 2),    # resume from one cached page row, GQA
    (8, 24, 2),     # small slab deep in the sequence
    (32, 32, 4),    # wide slab, wide GQA group
    (12, 20, 1),    # non-page-aligned slab boundary
])
def test_chunked_prefill_vs_oracle(chunk, cached, group, dtype):
    key = jax.random.PRNGKey(12)
    ks = jax.random.split(key, 2)
    B, Hk, D, page, pps = 2, 2, 32, 8, 12
    H = Hk * group
    k_pages, v_pages, page_table = _page_scene(ks[0], B, Hk, D, page, pps,
                                               dtype)
    q = _rand(ks[1], (B, chunk, H, D), dtype)
    # second sequence resumes from a non-page-aligned offset
    q_offsets = jnp.array([cached, max(0, cached - 3)], jnp.int32)
    kv_lens = q_offsets + chunk
    got = chunked_prefill_attention(
        q, k_pages, v_pages, page_table, q_offsets, kv_lens, interpret=True)
    expect = ref.chunked_prefill_attention_ref(
        q, k_pages, v_pages, page_table, q_offsets, kv_lens)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_chunked_prefill_softcap_vs_oracle():
    key = jax.random.PRNGKey(13)
    ks = jax.random.split(key, 2)
    B, chunk, Hk, group, D, page, pps = 1, 16, 2, 2, 16, 8, 8
    k_pages, v_pages, page_table = _page_scene(ks[0], B, Hk, D, page, pps,
                                               jnp.float32)
    q = _rand(ks[1], (B, chunk, Hk * group, D), jnp.float32)
    q_offsets = jnp.array([24], jnp.int32)
    kv_lens = q_offsets + chunk
    got = chunked_prefill_attention(
        q, k_pages, v_pages, page_table, q_offsets, kv_lens,
        logit_softcap=30.0, interpret=True)
    expect = ref.chunked_prefill_attention_ref(
        q, k_pages, v_pages, page_table, q_offsets, kv_lens,
        logit_softcap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_prefill_resumption_matches_full_causal(chunk):
    """Prefilling T tokens slab by slab — each chunk attending the pages
    written by chunks 0..N-1 — concatenates to one full causal pass."""
    key = jax.random.PRNGKey(14)
    ks = jax.random.split(key, 4)
    T, H, Hk, D, page = 32, 4, 2, 16, 8
    pps = T // page
    n_pages = pps + 2
    q_full = _rand(ks[0], (1, T, H, D), jnp.float32)
    k_full = _rand(ks[1], (1, T, Hk, D), jnp.float32)
    v_full = _rand(ks[2], (1, T, Hk, D), jnp.float32)
    perm = jax.random.permutation(ks[3], n_pages)[:pps].astype(jnp.int32)
    page_table = perm[None, :]
    k_pages = jnp.zeros((n_pages, page, Hk, D), jnp.float32)
    v_pages = jnp.zeros_like(k_pages)
    outs = []
    for s in range(0, T, chunk):
        # the caller's contract: scatter the slab's K/V first...
        for t in range(s, s + chunk):
            k_pages = k_pages.at[perm[t // page], t % page].set(k_full[0, t])
            v_pages = v_pages.at[perm[t // page], t % page].set(v_full[0, t])
        # ...then attend it against everything resident so far
        outs.append(chunked_prefill_attention(
            q_full[:, s:s + chunk], k_pages, v_pages, page_table,
            jnp.array([s], jnp.int32), jnp.array([s + chunk], jnp.int32),
            interpret=True))
    got = jnp.concatenate(outs, axis=1)
    expect = ref.mha_naive(q_full, k_full, v_full, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# page-table tail poisoning (index-map clamp)
# ---------------------------------------------------------------------------

def test_safe_page_index_never_reads_poisoned_tail():
    page = 8
    page_table = jnp.array([[3, 9, 1, 777_777, -5, 123_456]], jnp.int32)
    seq_lens = jnp.array([17], jnp.int32)        # 3 valid pages
    valid = {3, 9, 1}
    for p in range(page_table.shape[1]):
        got = int(safe_page_index(page_table, seq_lens, 0, p, page))
        assert got in valid, (p, got)
        assert got == (int(page_table[0, p]) if p < 3 else 1)
    # empty sequence: clamp to the first table entry, never past it
    empty = jnp.array([0], jnp.int32)
    for p in range(page_table.shape[1]):
        assert int(safe_page_index(page_table, empty, 0, p, page)) == 3


def test_paged_kernels_ignore_poisoned_tail_entries():
    """Table slots past ceil(seq_len / page) are allocator garbage; all
    three paged kernels must produce clean-table results anyway."""
    key = jax.random.PRNGKey(15)
    ks = jax.random.split(key, 4)
    B, H, Hk, D, page, pps = 2, 4, 2, 32, 8, 6
    k_pages, v_pages, clean = _page_scene(ks[0], B, Hk, D, page, pps,
                                          jnp.float32)
    n_pages = k_pages.shape[0]
    seq_lens = jnp.array([11, 37], jnp.int32)
    poisoned = np.asarray(clean).copy()
    for b, n in enumerate([11, 37]):
        poisoned[b, -(-n // page):] = n_pages * 13 + b   # far out of range
    poisoned = jnp.asarray(poisoned)

    q = _rand(ks[1], (B, H, D), jnp.float32)
    got = paged_decode_attention(q, k_pages, v_pages, poisoned, seq_lens,
                                 interpret=True)
    expect = ref.paged_decode_attention_ref(q, k_pages, v_pages, clean,
                                            seq_lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)

    k_new = _rand(ks[2], (B, Hk, D), jnp.float32)
    v_new = _rand(ks[3], (B, Hk, D), jnp.float32)
    got = batched_paged_decode_attention(
        q, k_pages, v_pages, poisoned, seq_lens, k_new, v_new,
        interpret=True)
    expect = ref.batched_paged_decode_attention_ref(
        q, k_pages, v_pages, clean, seq_lens, k_new, v_new)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)

    chunk = 8
    qc = _rand(ks[1], (B, chunk, H, D), jnp.float32)
    q_offsets = seq_lens - chunk
    got = chunked_prefill_attention(
        qc, k_pages, v_pages, poisoned, q_offsets, seq_lens, interpret=True)
    expect = ref.chunked_prefill_attention_ref(
        qc, k_pages, v_pages, clean, q_offsets, seq_lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan (Mamba-2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,L,H,G,P,N,chunk", [
    (1, 128, 4, 1, 16, 16, 32),
    (2, 256, 8, 2, 32, 64, 64),
    (1, 64, 2, 1, 64, 128, 64),
])
def test_ssd_scan_vs_naive(B, L, H, G, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    x = _rand(ks[0], (B, L, H, P), dtype) * 0.5
    a = -jnp.abs(_rand(ks[1], (B, L, H), jnp.float32)) * 0.1
    b = _rand(ks[2], (B, L, G, N), dtype) * 0.5
    c = _rand(ks[3], (B, L, G, N), dtype) * 0.5
    out = ssd_scan(x, a.astype(dtype), b, c, chunk=chunk, interpret=True)
    expect = ref.ssd_naive(x, a.astype(dtype), b, c)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_chunked_matches_naive_and_carries_state():
    ks = jax.random.split(jax.random.PRNGKey(8), 4)
    B, L, H, G, P, N, Q = 2, 192, 4, 2, 16, 32, 64
    x = _rand(ks[0], (B, L, H, P), jnp.float32) * 0.5
    a = -jnp.abs(_rand(ks[1], (B, L, H), jnp.float32)) * 0.1
    b = _rand(ks[2], (B, L, G, N), jnp.float32) * 0.5
    c = _rand(ks[3], (B, L, G, N), jnp.float32) * 0.5
    y, state = ref.ssd_chunked(x, a, b, c, chunk=Q, return_final_state=True)
    expect = ref.ssd_naive(x, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               atol=1e-4, rtol=1e-4)
    # final state equals stepping the recurrence token by token
    h = jnp.zeros((B, H, P, N), jnp.float32)
    for t in range(L):
        _, h = ref.ssm_decode_step_ref(h, x[:, t], a[:, t], b[:, t], c[:, t])
    np.testing.assert_allclose(np.asarray(state), np.asarray(h),
                               atol=1e-3, rtol=1e-3)


def test_ssd_decode_step_matches_prefill_continuation():
    """prefill L tokens then decode 1 == full scan over L+1 tokens."""
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    B, L, H, G, P, N, Q = 1, 64, 2, 1, 16, 16, 32
    x = _rand(ks[0], (B, L + 1, H, P), jnp.float32) * 0.5
    a = -jnp.abs(_rand(ks[1], (B, L + 1, H), jnp.float32)) * 0.1
    b = _rand(ks[2], (B, L + 1, G, N), jnp.float32) * 0.5
    c = _rand(ks[3], (B, L + 1, G, N), jnp.float32) * 0.5
    y_full = ref.ssd_naive(x, a, b, c)
    _, state = ref.ssd_chunked(x[:, :L], a[:, :L], b[:, :L], c[:, :L],
                               chunk=Q, return_final_state=True)
    y_tok, _ = ref.ssm_decode_step_ref(state, x[:, L], a[:, L], b[:, L],
                                       c[:, L])
    np.testing.assert_allclose(np.asarray(y_tok), np.asarray(y_full[:, L]),
                               atol=1e-4, rtol=1e-4)
