"""Pallas kernels vs pure-jnp oracles, executed with interpret=True on
CPU. Shape/dtype sweeps per kernel + chunked-form cross-validation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, paged_decode_attention, ssd_scan
from repro.kernels import ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Lq,Lk,H,Hk,D", [
    (1, 128, 128, 4, 4, 64),       # MHA square
    (2, 128, 128, 4, 2, 32),       # GQA
    (1, 64, 256, 8, 1, 64),        # MQA, decode-style Lq < Lk
    (2, 200, 200, 3, 3, 48),       # ragged (padding path)
])
def test_flash_attention_causal(B, Lq, Lk, H, Hk, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, Lq, H, D), dtype)
    k = _rand(ks[1], (B, Lk, Hk, D), dtype)
    v = _rand(ks[2], (B, Lk, Hk, D), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                          interpret=True)
    expect = ref.mha_naive(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, L, H, Hk, D = 1, 256, 4, 2, 32
    q = _rand(ks[0], (B, L, H, D), jnp.float32)
    k = _rand(ks[1], (B, L, Hk, D), jnp.float32)
    v = _rand(ks[2], (B, L, Hk, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_kv=64, interpret=True)
    expect = ref.mha_naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal_and_softcap():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, L, H, D = 1, 128, 2, 64
    q = _rand(ks[0], (B, L, H, D), jnp.float32)
    k = _rand(ks[1], (B, L, H, D), jnp.float32)
    v = _rand(ks[2], (B, L, H, D), jnp.float32)
    out = flash_attention(q, k, v, causal=False, logit_softcap=30.0,
                          block_q=64, block_kv=64, interpret=True)
    expect = ref.mha_naive(q, k, v, causal=False, logit_softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_prefix_lm():
    """PaliGemma-style: prefix keys visible to all queries."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, L, H, D, P = 1, 128, 2, 32, 16
    q = _rand(ks[0], (B, L, H, D), jnp.float32)
    k = _rand(ks[1], (B, L, H, D), jnp.float32)
    v = _rand(ks[2], (B, L, H, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, prefix_len=P,
                          block_q=64, block_kv=64, interpret=True)
    expect = ref.mha_naive(q, k, v, causal=True, prefix_len=P)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_chunked_reference_matches_naive():
    """The jnp chunked form (what non-TPU backends lower) == naive."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    B, L, H, Hk, D = 2, 160, 4, 2, 32
    q = _rand(ks[0], (B, L, H, D), jnp.float32)
    k = _rand(ks[1], (B, L, Hk, D), jnp.float32)
    v = _rand(ks[2], (B, L, Hk, D), jnp.float32)
    for kw in (dict(causal=True), dict(causal=False),
               dict(causal=True, window=48),
               dict(causal=True, prefix_len=8)):
        got = ref.flash_attention_chunked(q, k, v, block_kv=64, **kw)
        expect = ref.mha_naive(q, k, v, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hk,D,page,pages_per_seq", [
    (2, 4, 2, 64, 16, 8),
    (3, 8, 1, 32, 32, 4),
    (1, 4, 4, 128, 16, 16),
])
def test_paged_decode_attention(B, H, Hk, D, page, pages_per_seq, dtype):
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 4)
    n_pages = B * pages_per_seq + 3
    q = _rand(ks[0], (B, H, D), dtype)
    k_pages = _rand(ks[1], (n_pages, page, Hk, D), dtype)
    v_pages = _rand(ks[2], (n_pages, page, Hk, D), dtype)
    # each sequence gets a random non-overlapping page set
    perm = jax.random.permutation(ks[3], n_pages)[:B * pages_per_seq]
    page_table = perm.reshape(B, pages_per_seq).astype(jnp.int32)
    seq_lens = jnp.array(
        [1 + (7 * i) % (page * pages_per_seq) for i in range(B)], jnp.int32)
    out = paged_decode_attention(q, k_pages, v_pages, page_table, seq_lens,
                                 interpret=True)
    expect = ref.paged_decode_attention_ref(q, k_pages, v_pages, page_table,
                                            seq_lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_paged_equals_contiguous():
    """Paged pool gather == contiguous-cache decode attention."""
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 4)
    B, H, Hk, D, page, pps = 2, 4, 2, 32, 16, 4
    S = page * pps
    q = _rand(ks[0], (B, H, D), jnp.float32)
    k_cache = _rand(ks[1], (B, S, Hk, D), jnp.float32)
    v_cache = _rand(ks[2], (B, S, Hk, D), jnp.float32)
    lens = jnp.array([37, 61], jnp.int32)
    # lay the contiguous cache into pages
    k_pages = k_cache.reshape(B * pps, page, Hk, D)
    v_pages = v_cache.reshape(B * pps, page, Hk, D)
    page_table = jnp.arange(B * pps, dtype=jnp.int32).reshape(B, pps)
    got = ref.paged_decode_attention_ref(q, k_pages, v_pages, page_table, lens)
    expect = ref.decode_attention_ref(q, k_cache, v_cache, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# SSD scan (Mamba-2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,L,H,G,P,N,chunk", [
    (1, 128, 4, 1, 16, 16, 32),
    (2, 256, 8, 2, 32, 64, 64),
    (1, 64, 2, 1, 64, 128, 64),
])
def test_ssd_scan_vs_naive(B, L, H, G, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    x = _rand(ks[0], (B, L, H, P), dtype) * 0.5
    a = -jnp.abs(_rand(ks[1], (B, L, H), jnp.float32)) * 0.1
    b = _rand(ks[2], (B, L, G, N), dtype) * 0.5
    c = _rand(ks[3], (B, L, G, N), dtype) * 0.5
    out = ssd_scan(x, a.astype(dtype), b, c, chunk=chunk, interpret=True)
    expect = ref.ssd_naive(x, a.astype(dtype), b, c)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_chunked_matches_naive_and_carries_state():
    ks = jax.random.split(jax.random.PRNGKey(8), 4)
    B, L, H, G, P, N, Q = 2, 192, 4, 2, 16, 32, 64
    x = _rand(ks[0], (B, L, H, P), jnp.float32) * 0.5
    a = -jnp.abs(_rand(ks[1], (B, L, H), jnp.float32)) * 0.1
    b = _rand(ks[2], (B, L, G, N), jnp.float32) * 0.5
    c = _rand(ks[3], (B, L, G, N), jnp.float32) * 0.5
    y, state = ref.ssd_chunked(x, a, b, c, chunk=Q, return_final_state=True)
    expect = ref.ssd_naive(x, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               atol=1e-4, rtol=1e-4)
    # final state equals stepping the recurrence token by token
    h = jnp.zeros((B, H, P, N), jnp.float32)
    for t in range(L):
        _, h = ref.ssm_decode_step_ref(h, x[:, t], a[:, t], b[:, t], c[:, t])
    np.testing.assert_allclose(np.asarray(state), np.asarray(h),
                               atol=1e-3, rtol=1e-3)


def test_ssd_decode_step_matches_prefill_continuation():
    """prefill L tokens then decode 1 == full scan over L+1 tokens."""
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    B, L, H, G, P, N, Q = 1, 64, 2, 1, 16, 16, 32
    x = _rand(ks[0], (B, L + 1, H, P), jnp.float32) * 0.5
    a = -jnp.abs(_rand(ks[1], (B, L + 1, H), jnp.float32)) * 0.1
    b = _rand(ks[2], (B, L + 1, G, N), jnp.float32) * 0.5
    c = _rand(ks[3], (B, L + 1, G, N), jnp.float32) * 0.5
    y_full = ref.ssd_naive(x, a, b, c)
    _, state = ref.ssd_chunked(x[:, :L], a[:, :L], b[:, :L], c[:, :L],
                               chunk=Q, return_final_state=True)
    y_tok, _ = ref.ssm_decode_step_ref(state, x[:, L], a[:, L], b[:, L],
                                       c[:, L])
    np.testing.assert_allclose(np.asarray(y_tok), np.asarray(y_full[:, L]),
                               atol=1e-4, rtol=1e-4)
