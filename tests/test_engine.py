"""The real JAX continuous-batching engine driving DriftScheduler."""

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.estimator import DriftConfig
from repro.core.request import Category, Request, TenantTier
from repro.core.scheduler import DriftScheduler
from repro.models.registry import get_api
from repro.serving.engine import EngineConfig, ServingEngine
from repro.workload.generator import GeneratorConfig, WorkloadGenerator


def _engine(policy="fifo", n_slots=4, arch="smollm-135m"):
    cfg = smoke_config(arch)
    api = get_api(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    sched = DriftScheduler(policy=policy)
    eng = ServingEngine(cfg, params, sched,
                        EngineConfig(n_slots=n_slots, max_len=96,
                                     prompt_buckets=(16,)))
    return eng, sched


def _submit_n(sched, n, seed=0):
    gen = WorkloadGenerator(GeneratorConfig(
        total_requests=n, calibration_requests=n,
        max_tokens=48, seed=seed))
    plan = gen.plan(seed=seed)
    for t, r in plan.calibration:
        sched.submit(r, t)
    return [r for _, r in plan.calibration]


def test_engine_completes_all_requests():
    eng, sched = _engine()
    reqs = _submit_n(sched, 12)
    m = eng.run_until_drained(max_steps=5000)
    assert m.n_completed == 12
    assert sched.queue_depth() == 0
    assert not eng.active_slots()


def test_engine_observed_lengths_feed_drift():
    eng, sched = _engine()
    reqs = _submit_n(sched, 10)
    eng.run_until_drained(max_steps=5000)
    assert sum(sched.bias_store.update_counts().values()) == 10
    for r in sched.completed:
        assert r.observed_output_tokens >= 1
        # oracle EOS: observed == min(true, cap, slot budget)
        assert r.observed_output_tokens <= r.max_tokens


def test_engine_continuous_batching_interleaves():
    """More requests than slots: slots must turn over (join/leave)."""
    eng, sched = _engine(n_slots=2)
    _submit_n(sched, 8)
    m = eng.run_until_drained(max_steps=5000)
    assert m.n_completed == 8


def test_engine_sjf_prefers_short_jobs():
    eng, sched = _engine(policy="sjf", n_slots=1)
    # one long report then several short QAs; SJF should run shorts first
    long_r = Request(tenant=TenantTier.BATCH, category=Category.REPORT,
                     prompt="write a detailed report on dns outages",
                     max_tokens=48, true_output_tokens=48)
    shorts = [Request(tenant=TenantTier.PREMIUM, category=Category.SHORT_QA,
                      prompt="what is dns?", max_tokens=48,
                      true_output_tokens=4) for _ in range(3)]
    sched.submit(long_r, 0.0)
    for s in shorts:
        sched.submit(s, 0.01)
    eng.run_until_drained(max_steps=5000)
    order = [r.req_id for r in sched.completed]
    assert order.index(long_r.req_id) == len(order) - 1


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-1.2b"])
def test_engine_runs_ssm_families(arch):
    eng, sched = _engine(arch=arch, n_slots=2)
    _submit_n(sched, 4)
    m = eng.run_until_drained(max_steps=5000)
    assert m.n_completed == 4


def test_paged_engine_matches_contiguous_completions():
    """vLLM-style paged engine mode: same scheduler behaviour, same
    observed lengths, allocator fully drains."""
    import numpy as np
    cfg = smoke_config("smollm-135m")
    api = get_api(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))

    def run(paged):
        sched = DriftScheduler(policy="fifo")
        eng = ServingEngine(cfg, params, sched,
                            EngineConfig(n_slots=3, max_len=96,
                                         prompt_buckets=(16,),
                                         paged=paged, page_size=8))
        gen = WorkloadGenerator(GeneratorConfig(
            total_requests=8, calibration_requests=8,
            max_tokens=24, seed=3))
        for t, r in gen.plan(seed=3).calibration:
            sched.submit(r, t)
        m = eng.run_until_drained(max_steps=5000)
        return eng, sched, m

    eng_p, sched_p, m_p = run(paged=True)
    eng_c, sched_c, m_c = run(paged=False)
    assert m_p.n_completed == m_c.n_completed == 8
    obs_p = sorted(r.observed_output_tokens for r in sched_p.completed)
    obs_c = sorted(r.observed_output_tokens for r in sched_c.completed)
    assert obs_p == obs_c                     # oracle-EOS targets agree
    assert eng_p.alloc.free_pages == eng_p.alloc.n_pages  # all freed


def test_paged_engine_rejects_ssm():
    import pytest as _pytest
    cfg = smoke_config("mamba2-2.7b")
    api = get_api(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    with _pytest.raises(ValueError):
        ServingEngine(cfg, params, DriftScheduler(policy="fifo"),
                      EngineConfig(paged=True))
