"""Quickstart: the DriftSched public API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a scheduler, submits a handful of multi-tenant requests, watches
the adaptive token estimator learn runtime token drift (Eq. 1-6), and
shows how the learned bias changes admission-time classification.
"""

from repro.core import (Category, DriftConfig, DriftScheduler, Request,
                        TenantTier)

sched = DriftScheduler(policy="sjf", config=DriftConfig())

print("=== admission-time estimation (static, bias=1.0) ===")
r = Request(tenant=TenantTier.PREMIUM, category=Category.REPORT,
            prompt="Write a detailed incident report on the DNS outage.")
sched.submit(r, now=0.0)
e = r.estimate
print(f"T_base={e.t_base:.0f} B={e.bias:.2f} S={e.safety:.2f} "
      f"F={e.f_input:.2f} -> budget={e.t_budget:.0f} "
      f"class={e.job_class.value}")

# dispatch + completion: the model actually generated far fewer tokens
# than the static estimate (runtime token drift)
req = sched.dispatch(now=0.1)
sched.complete(req, observed_tokens=410, now=5.0)

print("\n=== after feedback, the report bias has adapted ===")
for i in range(30):   # a few more drifting reports
    r = Request(tenant=TenantTier.STANDARD, category=Category.REPORT,
                prompt="Write a full post-incident report covering etcd.")
    sched.submit(r, now=10.0 + i)
    d = sched.dispatch(now=10.0 + i)
    sched.complete(d, observed_tokens=400 + 5 * i, now=12.0 + i)

print("learned bias:", {k: round(v, 3)
                        for k, v in sched.bias_store.snapshot().items()})

r2 = Request(tenant=TenantTier.PREMIUM, category=Category.REPORT,
             prompt="Write a detailed incident report on the DNS outage.")
sched.submit(r2, now=100.0)
e2 = r2.estimate
print(f"new estimate: budget={e2.t_budget:.0f} class={e2.job_class.value} "
      f"(was {e.t_budget:.0f}/{e.job_class.value})")

stats = sched.drift.stats()
print(f"\ndrift so far: n={stats.n} MAE={stats.mae:.1f} "
      f"mean_error={stats.mean_error:+.1f} "
      f"(positive = static over-estimation, the paper's drift direction)")
