"""End-to-end driver: the REAL JAX continuous-batching engine serving
batched multi-tenant requests under DriftSched (the paper's kind of
workload, deliverable b).

    PYTHONPATH=src python examples/multi_tenant_serving.py \
        [--arch smollm-135m] [--policy sjf] [--requests 32]

The engine decodes every active slot one token per iteration (slot-ring
continuous batching), admits from the DriftScheduler queues, retires at
oracle-EOS, and feeds observed lengths back into the drift compensator
— the identical state machine the paper benchmarks, on a real model.
"""

import argparse
import time

import jax

from repro.configs import ARCHS, smoke_config
from repro.core.estimator import DriftConfig
from repro.core.scheduler import DriftScheduler
from repro.models.registry import get_api
from repro.serving.engine import EngineConfig, ServingEngine
from repro.workload.generator import GeneratorConfig, WorkloadGenerator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCHS)
    ap.add_argument("--policy", default="sjf")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    api = get_api(cfg)
    print(f"model={cfg.name} ({cfg.param_count()/1e6:.2f}M params, "
          f"family={cfg.family}) slots={args.slots} policy={args.policy}")
    params = api.init(cfg, jax.random.PRNGKey(0))

    sched = DriftScheduler(policy=args.policy, config=DriftConfig())
    engine = ServingEngine(cfg, params, sched,
                           EngineConfig(n_slots=args.slots, max_len=128,
                                        prompt_buckets=(16, 32)))

    gen = WorkloadGenerator(GeneratorConfig(
        total_requests=args.requests,
        calibration_requests=args.requests,
        max_tokens=64, seed=0))
    for t, r in gen.plan(seed=0).calibration:
        sched.submit(r, t)
    print(f"submitted {args.requests} requests across 3 tenants")

    t0 = time.time()
    metrics = engine.run_until_drained()
    wall = time.time() - t0
    print(f"\ndrained in {engine.step_count} engine steps "
          f"({wall:.1f}s wall on CPU)")
    print(f"completed={metrics.n_completed} "
          f"throughput={metrics.n_completed/engine.step_count:.2f} "
          "req/engine-step")
    for t, v in metrics.per_tenant.items():
        print(f"tenant {t:9s} mean latency={v['latency']['mean']:7.1f} "
              f"steps, wait={v['queue_wait']['mean']:7.1f}")
    print("learned bias:",
          {k: round(v, 3) for k, v in sched.bias_store.snapshot().items()})
    obs = [r.observed_output_tokens for r in sched.completed]
    print(f"observed output tokens: min={min(obs)} max={max(obs)} "
          f"mean={sum(obs)/len(obs):.1f}")


if __name__ == "__main__":
    main()
