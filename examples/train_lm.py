"""End-to-end training example: train a language model with the full
substrate (data pipeline -> model -> AdamW+ZeRO -> checkpointing).

    PYTHONPATH=src python examples/train_lm.py                # fast smoke
    PYTHONPATH=src python examples/train_lm.py --preset full  # 135M model

The smoke preset trains the reduced smollm config for 200 steps on the
synthetic copy-task corpus — loss drops visibly within seconds. The
full preset is the real 135M SmolLM config (slow on this CPU
container; the production path for it is the train_4k dry-run cell).
"""

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    train_main([
        "--arch", "smollm-135m",
        "--preset", args.preset,
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "128",
        "--dataset", "synthetic",
        "--checkpoint-dir", "/tmp/repro_train_lm",
        "--checkpoint-every", "100",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    main()
