"""Runtime token drift compensation, BIAS=OFF vs BIAS=ON — the paper's
core experiment (Fig 5, Fig 8, Table VII) on the full 3000-request
protocol.

    PYTHONPATH=src python examples/drift_demo.py [--policy sjf]
"""

import argparse

from repro.core.drift import error_reduction
from repro.core.estimator import DriftConfig
from repro.core.scheduler import DriftScheduler
from repro.serving.simulator import SimConfig, WorkerSimulator
from repro.workload.generator import GeneratorConfig, WorkloadGenerator


def run(policy: str, bias: bool, seed: int = 1):
    plan = WorkloadGenerator(GeneratorConfig(seed=seed)).plan(seed=seed)
    sched = DriftScheduler(policy=policy,
                           config=DriftConfig(bias_enabled=bias))
    sim = WorkerSimulator(sched, plan, SimConfig(seed=seed))
    metrics = sim.run()
    return sched, sim, metrics


def sparkline(values, width=60):
    if not values:
        return ""
    blocks = " .:-=+*#%@"
    lo, hi = min(values), max(values)
    span = (hi - lo) or 1.0
    idx = [int((v - lo) / span * (len(blocks) - 1)) for v in values]
    stride = max(len(idx) // width, 1)
    return "".join(blocks[i] for i in idx[::stride][:width])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="fifo")
    args = ap.parse_args()

    print(f"policy={args.policy}; 3000 requests "
          "(1000 calibration + 2000 stress)\n")
    s_off, _, m_off = run(args.policy, bias=False)
    s_on, sim, m_on = run(args.policy, bias=True)

    print("=== Fig 5: bias convergence (BIAS=ON) ===")
    hist = s_on.bias_store.history
    for cat in ("short_qa", "summary", "technical", "report"):
        vals = [h.bias for h in hist if h.category == cat]
        print(f"{cat:10s} 1.0 -> {vals[-1]:.3f}  [{sparkline(vals)}]")
    print(f"(paper band: 0.79-0.84; stress phase begins at "
          f"t={sim.phase_boundary:.0f}s)\n")

    off, on = s_off.drift.stats(), s_on.drift.stats()
    red = error_reduction(off, on)
    print("=== Table VII: estimation error ===")
    print(f"BIAS=OFF  MAE={off.mae:7.1f}  RMSE={off.rmse:7.1f}  "
          f"mean_error={off.mean_error:+7.1f}")
    print(f"BIAS=ON   MAE={on.mae:7.1f}  RMSE={on.rmse:7.1f}  "
          f"mean_error={on.mean_error:+7.1f}")
    print(f"reduction MAE {red['mae_reduction_pct']:.1f}% "
          f"(paper 38.8%)  RMSE {red['rmse_reduction_pct']:.1f}% "
          f"(paper 40.5%)\n")

    mis_off = s_off.drift.misclassification_rate(
        s_off.estimator.classify_budget)
    mis_on = s_on.drift.misclassification_rate(
        s_on.estimator.classify_budget)
    print("=== Fig 2: workload misclassification ===")
    print(f"BIAS=OFF {100*mis_off:.1f}%  ->  BIAS=ON {100*mis_on:.1f}%")

    print("\n=== e2e latency side effect ===")
    print(f"BIAS=OFF P50={m_off.e2e.p50:.1f}s  "
          f"BIAS=ON P50={m_on.e2e.p50:.1f}s")


if __name__ == "__main__":
    main()
